//! The persistent event core: a single global event queue scheduling
//! **individual tasks** from many stages of many jobs at once over the
//! modeled cluster.
//!
//! [`EventSim`] owns the cluster's contended state — per-node core slots
//! and the processor-shared disk/NIC flow sets — for the whole lifetime
//! of a simulation. Stages are [`submit`](EventSim::submit)ted as they
//! become runnable (the engine submits a stage the moment its DAG
//! parents complete) and the core interleaves their tasks freely: a
//! reduce stage of job A shares disks and NICs with a map stage of job B
//! at fair fluid-flow rates, exactly as concurrent Spark jobs contend on
//! one cluster.
//!
//! Tasks are first-class schedulable units, each with its own launch and
//! finish events:
//!
//! * **Delay scheduling** (`spark.locality.wait`, [`SimPolicy`]): a task
//!   with preferred nodes *holds* for up to `locality_wait` simulated
//!   seconds (from its stage's submission) for a free core on one of
//!   them, then degrades to ANY placement. A stage whose pending tasks
//!   are all holding is skipped by admission entirely — later stages and
//!   other jobs take the cores, as in Zaharia's delay scheduler.
//! * **Speculative execution** (`spark.speculation`, [`SpecPolicy`]):
//!   once a stage has at least `quantile` of its tasks done, any running
//!   task whose elapsed time exceeds `multiplier` × the median successful
//!   duration is cloned onto a *different* node. The first finisher wins;
//!   the loser is cancelled — its core freed, its processor-shared flow
//!   withdrawn mid-stream, and the stage's resource meters refunded for
//!   the work it never completed.
//!
//! **Which** pending task gets a freed core is delegated to a pluggable
//! [`Scheduler`] — the analogue of Spark's `spark.scheduler.mode`:
//!
//! * [`FifoScheduler`] — earlier-submitted jobs win; within a job,
//!   earlier-submitted stages win (Spark's default FIFO pool ordering by
//!   job submission time).
//! * [`FairScheduler`] — Spark's fair-scheduling algorithm over per-job
//!   [`PoolSpec`]s: pools below their `minShare` first (by
//!   running/minShare), then by running/`weight`. With default pools it
//!   reduces to fewest-running-tasks-first.
//!
//! Time only moves at events (task phase completions, stage completion
//! barriers, locality-hold expiries, and speculation deadlines); between
//! events every processor-shared flow progresses at its cached fair-share
//! rate — the standard fluid-flow DES. Everything is deterministic in
//! `(submission order, SimOpts seed)`: repeated runs produce bit-identical
//! clocks, and with `locality_wait == 0`, speculation off, and no
//! straggler model the core reproduces the PR-1 stage-granular behavior
//! bit for bit.
//!
//! A stage *completes* `waves × task_overhead` after its last task
//! finishes (the per-wave scheduling/launch overhead the barrier model
//! charged at stage granularity); its [`StageCompletion`] — which also
//! carries the node every task actually ran on, so the engine can derive
//! cache-locality preferences for child stages — is surfaced to the
//! driver from [`advance`](EventSim::advance).

use super::{Phase, SimOpts, StageStats, TaskSpec};
use crate::cluster::{ClusterSpec, NodeId};
use crate::util::stats::Summary;
use crate::util::Prng;
use std::cmp::Ordering;
use std::collections::VecDeque;
use std::fmt;

/// Identifies one submitting job within an [`EventSim`] (the engine uses
/// the job's index in the submission batch).
pub type JobId = usize;

/// Handle for a submitted stage, unique within one [`EventSim`].
pub type StageHandle = usize;

/// `spark.scheduler.mode` — how concurrently runnable tasks from
/// different jobs are ordered onto free cores.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SchedulerMode {
    /// Jobs get cores in submission order (Spark's default).
    #[default]
    Fifo,
    /// Running-task counts are balanced across jobs, honoring per-pool
    /// `weight` / `minShare`.
    Fair,
}

impl SchedulerMode {
    pub const ALL: [SchedulerMode; 2] = [SchedulerMode::Fifo, SchedulerMode::Fair];

    pub fn config_name(self) -> &'static str {
        match self {
            SchedulerMode::Fifo => "FIFO",
            SchedulerMode::Fair => "FAIR",
        }
    }

    pub fn from_config_name(s: &str) -> Option<SchedulerMode> {
        match s.trim().to_ascii_uppercase().as_str() {
            "FIFO" => Some(SchedulerMode::Fifo),
            "FAIR" => Some(SchedulerMode::Fair),
            _ => None,
        }
    }
}

impl fmt::Display for SchedulerMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.config_name())
    }
}

/// FAIR-pool configuration for one job — Spark's per-pool `weight` /
/// `minShare` from the fair-scheduler allocation file.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PoolSpec {
    /// Relative core share once no pool is below its minimum.
    pub weight: f64,
    /// Cores this pool is entitled to before weighted sharing applies.
    pub min_share: u32,
}

impl Default for PoolSpec {
    fn default() -> PoolSpec {
        PoolSpec { weight: 1.0, min_share: 0 }
    }
}

/// `spark.speculation.*`: once a stage has at least `quantile` of its
/// tasks finished, tasks running longer than `multiplier` × the median
/// successful task duration get a backup copy on another node; the first
/// finisher wins and the loser's resource flows are cancelled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpecPolicy {
    /// Fraction of the stage's tasks that must be complete before
    /// speculation kicks in (Spark default 0.75).
    pub quantile: f64,
    /// How many times slower than the median a task must be to get a
    /// backup (Spark default 1.5).
    pub multiplier: f64,
}

/// Core-wide scheduling policy beyond the [`Scheduler`] trait: delay
/// scheduling and speculative execution. `Default` disables both — the
/// PR-1 stage-granular behavior, bit for bit.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SimPolicy {
    /// `spark.locality.wait` in simulated seconds: how long a task with
    /// preferred nodes holds for a local core before degrading to ANY.
    /// The hold window is measured from its stage's submission — a
    /// deterministic simplification of Spark's per-level reset timer.
    pub locality_wait: f64,
    /// `spark.speculation` (`None` = off).
    pub speculation: Option<SpecPolicy>,
}

/// What a [`Scheduler`] sees of one runnable stage when picking the next
/// task to admit. Candidates are stages with at least one *admissible*
/// pending task under the current free cores and locality state — a
/// stage whose pending tasks are all holding for busy local nodes is not
/// offered (delay scheduling).
#[derive(Clone, Copy, Debug)]
pub struct StageView {
    /// Handle of the stage (return this from [`Scheduler::pick`]).
    pub handle: StageHandle,
    /// Submitting job.
    pub job: JobId,
    /// Global submission sequence number of the stage.
    pub seq: usize,
    /// Tasks of this stage still waiting for a core.
    pub pending: usize,
    /// Tasks of this stage's *job* currently holding cores.
    pub job_running: usize,
    /// FAIR-pool weight of the job (1.0 unless configured).
    pub weight: f64,
    /// FAIR-pool minimum core share of the job (0 unless configured).
    pub min_share: u32,
}

/// Task-admission policy: given the stages that currently have admissible
/// pending tasks, choose the stage whose next task gets the free core.
///
/// Implementations must be deterministic functions of the view (the
/// event core's reproducibility guarantee depends on it).
pub trait Scheduler {
    /// Policy name for reports.
    fn name(&self) -> &'static str;

    /// Pick a stage from `candidates` (all have an admissible pending
    /// task; the slice is ordered by handle). Returning `None` leaves the
    /// cores idle until the next submission.
    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle>;
}

/// FIFO: lowest job id first (jobs are numbered in submission order),
/// then lowest stage submission sequence.
#[derive(Clone, Copy, Debug, Default)]
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle> {
        candidates.iter().min_by_key(|s| (s.job, s.seq)).map(|s| s.handle)
    }
}

/// FAIR: Spark's `FairSchedulingAlgorithm` over per-job pools — see
/// [`fair_order`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FairScheduler;

impl Scheduler for FairScheduler {
    fn name(&self) -> &'static str {
        "FAIR"
    }

    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle> {
        candidates.iter().min_by(|a, b| fair_order(a, b)).map(|s| s.handle)
    }
}

/// Spark's fair comparator: pools below their `minShare` come first
/// (ordered by running/minShare); otherwise pools order by
/// running/`weight`. Ties break on (job, seq), making the order total
/// and deterministic. With default pools (weight 1, minShare 0) this
/// reduces to fewest-running-tasks-first — the historical FAIR behavior,
/// bit for bit.
fn fair_order(a: &StageView, b: &StageView) -> Ordering {
    let a_needy = (a.job_running as u32) < a.min_share;
    let b_needy = (b.job_running as u32) < b.min_share;
    match (a_needy, b_needy) {
        (true, false) => return Ordering::Less,
        (false, true) => return Ordering::Greater,
        _ => {}
    }
    let (ra, rb) = if a_needy {
        (
            a.job_running as f64 / a.min_share.max(1) as f64,
            b.job_running as f64 / b.min_share.max(1) as f64,
        )
    } else {
        (
            a.job_running as f64 / a.weight.max(f64::MIN_POSITIVE),
            b.job_running as f64 / b.weight.max(f64::MIN_POSITIVE),
        )
    };
    ra.partial_cmp(&rb)
        .unwrap_or(Ordering::Equal)
        .then_with(|| (a.job, a.seq).cmp(&(b.job, b.seq)))
}

/// Instantiate the scheduler for a mode.
pub fn scheduler_for(mode: SchedulerMode) -> Box<dyn Scheduler> {
    match mode {
        SchedulerMode::Fifo => Box::new(FifoScheduler),
        SchedulerMode::Fair => Box::new(FairScheduler),
    }
}

/// Emitted by [`EventSim::advance`] when a submitted stage has fully
/// finished (all tasks done + the stage's wave overhead elapsed).
#[derive(Clone, Debug)]
pub struct StageCompletion {
    pub handle: StageHandle,
    pub job: JobId,
    /// Event-clock time of the completion.
    pub at: f64,
    pub stats: StageStats,
    /// The node each task's *winning* copy ran on, indexed by task — the
    /// engine derives cache-read locality preferences for child stages
    /// from this (cached blocks live where their writer actually ran).
    pub task_nodes: Vec<NodeId>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ResKind {
    Disk,
    Nic,
}

/// Per-task-copy run state.
struct Running {
    stage: StageHandle,
    task_idx: usize,
    node: NodeId,
    phase_idx: usize,
    /// For PS phases: remaining bytes.
    remaining: f64,
    /// For fixed-rate phases: absolute end time.
    end_time: f64,
    is_ps: bool,
    res: ResKind,
    started: f64,
    /// Rate computed during the event scan, reused by the advance pass
    /// (rates only change at events).
    rate: f64,
    /// Current phase is a metered CPU phase (for cancellation refunds).
    is_cpu: bool,
    /// This entry is a speculative backup copy.
    is_clone: bool,
}

/// Resource metering accumulated while a task enters phases.
#[derive(Default)]
struct Meter {
    cpu_secs: f64,
    disk_bytes: f64,
    net_bytes: f64,
}

/// Per-stage runtime state inside the core.
struct StageRt {
    job: JobId,
    seq: usize,
    /// Jittered (and possibly straggler-scaled) phase lists, one per task.
    phases: Vec<Vec<Phase>>,
    /// Re-jittered phase lists for speculative copies — no straggler
    /// factor, the backup lands on a healthy node. Empty when speculation
    /// is off.
    clone_phases: Vec<Vec<Phase>>,
    /// Preferred nodes per task (empty = ANY).
    preferred: Vec<Vec<NodeId>>,
    pending: VecDeque<usize>,
    /// How many pending tasks still carry a locality preference (drives
    /// the hold-expiry event scan).
    pending_pref: usize,
    /// Task finished (winning copy completed).
    done: Vec<bool>,
    /// Task has a speculative backup copy (launched at most once).
    cloned: Vec<bool>,
    /// Tasks not yet finished.
    unfinished: usize,
    submitted_at: f64,
    task_durations: Vec<f64>,
    /// Node the winning copy of each task ran on.
    task_nodes: Vec<NodeId>,
    /// Tasks launched on one of their preferred nodes.
    locality_hits: usize,
    /// Speculative copies launched.
    speculated: usize,
    cpu_secs: f64,
    disk_bytes: f64,
    net_bytes: f64,
    /// `waves × task_overhead`, charged between the last task finish and
    /// the stage's completion event.
    completion_overhead: f64,
    /// Absolute completion time, set when `unfinished` reaches zero.
    completion_due: Option<f64>,
    /// The completion event has been surfaced to the driver.
    emitted: bool,
}

/// The persistent, multi-stage, multi-job discrete-event simulator core
/// (see module docs).
pub struct EventSim<'a> {
    cluster: &'a ClusterSpec,
    scheduler: Box<dyn Scheduler>,
    policy: SimPolicy,
    now: f64,
    free_cores: Vec<i64>,
    disk_active: Vec<u32>,
    nic_active: Vec<u32>,
    running: Vec<Running>,
    stages: Vec<StageRt>,
    /// Running task-copy count per job (indexed by `JobId`).
    jobs_running: Vec<usize>,
    /// FAIR pool per job (default weight 1 / minShare 0).
    pools: Vec<PoolSpec>,
    /// Round-robin cursor for locality-free placement.
    rr: usize,
    /// Admission gate: only rescan pending work when cores were freed,
    /// stages were submitted, or a locality/speculation deadline passed
    /// since the last pass.
    admit_dirty: bool,
}

const EPS: f64 = 1e-9;

impl<'a> EventSim<'a> {
    /// A core with the default policy (no locality wait, no speculation)
    /// — the PR-1 stage-granular behavior.
    pub fn new(cluster: &'a ClusterSpec, scheduler: Box<dyn Scheduler>) -> EventSim<'a> {
        EventSim::with_policy(cluster, scheduler, SimPolicy::default())
    }

    /// A core with explicit delay-scheduling / speculation policy.
    pub fn with_policy(
        cluster: &'a ClusterSpec,
        scheduler: Box<dyn Scheduler>,
        policy: SimPolicy,
    ) -> EventSim<'a> {
        let nodes = cluster.nodes as usize;
        EventSim {
            cluster,
            scheduler,
            policy,
            now: 0.0,
            free_cores: vec![cluster.cores_per_node as i64; nodes],
            disk_active: vec![0u32; nodes],
            nic_active: vec![0u32; nodes],
            running: Vec::with_capacity(cluster.total_cores() as usize),
            stages: Vec::new(),
            jobs_running: Vec::new(),
            pools: Vec::new(),
            rr: 0,
            admit_dirty: false,
        }
    }

    /// Current event-clock time (seconds, simulated).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// The scheduling policy in force.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// The delay-scheduling / speculation policy in force.
    pub fn policy(&self) -> &SimPolicy {
        &self.policy
    }

    /// Assign `job` to a FAIR pool (weight / minShare). May be called
    /// before or after the job's first submission; jobs default to
    /// weight 1 / minShare 0.
    pub fn set_pool(&mut self, job: JobId, pool: PoolSpec) {
        if job >= self.pools.len() {
            self.pools.resize(job + 1, PoolSpec::default());
        }
        self.pools[job] = pool;
    }

    /// Submit a stage of `tasks` on behalf of `job`. CPU jitter is drawn
    /// per task, in task order, from a stream seeded by `opts.seed` —
    /// identical to the historical per-stage barrier runner, so a linear
    /// DAG under FIFO reproduces the barrier path bit for bit. The
    /// straggler tail (`opts.straggler`) and the speculative-copy
    /// re-jitter draw from their own dedicated streams, so enabling
    /// either never perturbs the base draws.
    pub fn submit(&mut self, job: JobId, tasks: &[TaskSpec], opts: &SimOpts) -> StageHandle {
        let mut rng = Prng::new(opts.seed ^ 0xD15C0);
        let mut srng = Prng::new(opts.seed ^ 0x57A6_61E5);
        let mut crng = if self.policy.speculation.is_some() {
            Some(Prng::new(opts.seed ^ 0xC1_0E5))
        } else {
            None
        };
        let mut phases: Vec<Vec<Phase>> = Vec::with_capacity(tasks.len());
        let mut clone_phases: Vec<Vec<Phase>> = Vec::new();
        for t in tasks {
            let mut factor = 1.0 + opts.jitter * (rng.f64() - 0.5) * 2.0;
            if let Some(s) = &opts.straggler {
                if s.prob > 0.0 && srng.f64() < s.prob {
                    factor *= s.factor.max(1.0);
                }
            }
            phases.push(scale_cpu(&t.phases, factor));
            if let Some(crng) = crng.as_mut() {
                let cf = 1.0 + opts.jitter * (crng.f64() - 0.5) * 2.0;
                clone_phases.push(scale_cpu(&t.phases, cf));
            }
        }
        let preferred: Vec<Vec<NodeId>> = tasks.iter().map(|t| t.preferred_nodes.clone()).collect();
        let pending_pref = preferred.iter().filter(|p| !p.is_empty()).count();

        // One wave overhead per `total_cores` tasks, charged between the
        // last task finish and the completion event (the engine's
        // downstream stages unlock only then).
        let waves =
            (tasks.len() as f64 / self.cluster.total_cores() as f64).ceil().max(1.0);
        let completion_overhead = waves * self.cluster.task_overhead;

        let handle = self.stages.len();
        let n = tasks.len();
        if job >= self.jobs_running.len() {
            self.jobs_running.resize(job + 1, 0);
        }
        if job >= self.pools.len() {
            self.pools.resize(job + 1, PoolSpec::default());
        }
        self.stages.push(StageRt {
            job,
            seq: handle,
            phases,
            clone_phases,
            preferred,
            pending: (0..n).collect(),
            pending_pref,
            done: vec![false; n],
            cloned: vec![false; n],
            unfinished: n,
            submitted_at: self.now,
            task_durations: Vec::with_capacity(n),
            task_nodes: vec![0; n],
            locality_hits: 0,
            speculated: 0,
            cpu_secs: 0.0,
            disk_bytes: 0.0,
            net_bytes: 0.0,
            completion_overhead,
            completion_due: if n == 0 { Some(self.now + completion_overhead) } else { None },
            emitted: false,
        });
        self.admit_dirty = true;
        handle
    }

    /// Advance the clock until the next stage completes; `None` once all
    /// submitted stages have completed (the sim stays usable — submit
    /// more and call again).
    pub fn advance(&mut self) -> Option<StageCompletion> {
        loop {
            if let Some(c) = self.pop_due_completion() {
                return Some(c);
            }
            self.admit();
            self.speculate();

            // ---- Find the next event (task phase end, stage completion
            // barrier, locality-hold expiry, or speculation deadline),
            // caching PS fair-share rates ----
            let mut dt = f64::INFINITY;
            for r in &mut self.running {
                let t = if r.is_ps {
                    let active = match r.res {
                        ResKind::Disk => self.disk_active[r.node as usize],
                        ResKind::Nic => self.nic_active[r.node as usize],
                    } as f64;
                    let cap = match r.res {
                        ResKind::Disk => self.cluster.disk_bw,
                        ResKind::Nic => self.cluster.net_bw,
                    };
                    r.rate = cap / active.max(1.0);
                    r.remaining / r.rate
                } else {
                    r.end_time - self.now
                };
                if t < dt {
                    dt = t;
                }
            }
            for s in &self.stages {
                if let Some(due) = s.completion_due {
                    if !s.emitted {
                        let t = due - self.now;
                        if t < dt {
                            dt = t;
                        }
                    }
                }
            }
            if self.policy.locality_wait > 0.0 {
                // A held task's hold expiry is an event: the admission
                // scan must rerun when a stage degrades to ANY.
                for s in &self.stages {
                    if s.pending_pref > 0 && !s.pending.is_empty() {
                        let t = s.submitted_at + self.policy.locality_wait - self.now;
                        if t > EPS && t < dt {
                            dt = t;
                        }
                    }
                }
            }
            if let Some(spec) = self.policy.speculation {
                // The instant a running task crosses multiplier × median
                // is an event (the median only moves at completions, which
                // are themselves events — so this scan is exact).
                let overhead = self.cluster.task_overhead;
                let mut memo: Vec<Option<Option<f64>>> = vec![None; self.stages.len()];
                for r in &self.running {
                    if r.is_clone {
                        continue;
                    }
                    let st = &self.stages[r.stage];
                    if st.done[r.task_idx] || st.cloned[r.task_idx] {
                        continue;
                    }
                    let th = *memo[r.stage].get_or_insert_with(|| spec_threshold(st, &spec));
                    let Some(th) = th else { continue };
                    let t = r.started + th - overhead - self.now;
                    if t > EPS && t < dt {
                        dt = t;
                    }
                }
            }
            if dt == f64::INFINITY {
                debug_assert!(self.running.is_empty());
                return None; // fully idle
            }
            let dt = dt.max(0.0);
            let prev_now = self.now;
            self.now += dt;
            if self.policy.locality_wait > 0.0 && !self.admit_dirty {
                // A hold expiry frees no cores but must re-trigger the
                // admission scan. Only mark dirty when this event actually
                // crossed a stage's hold deadline, so the core-freed
                // admission gate keeps its bite on the common path.
                // (Speculation deadlines need no admission rescan —
                // `speculate` runs every iteration regardless.)
                for s in &self.stages {
                    if s.pending_pref > 0 && !s.pending.is_empty() {
                        let dl = s.submitted_at + self.policy.locality_wait;
                        if dl <= self.now + EPS && dl > prev_now + EPS {
                            self.admit_dirty = true;
                            break;
                        }
                    }
                }
            }

            // ---- Advance all active flows by dt (cached pre-event
            // rates), then extract completions, then start successor
            // phases. Three separate passes so a phase that starts at
            // this event is never credited progress for the interval that
            // just elapsed. ----
            for r in &mut self.running {
                if r.is_ps {
                    r.remaining -= r.rate * dt;
                }
            }
            let mut finished: Vec<Running> = Vec::new();
            let mut i = 0;
            while i < self.running.len() {
                let done = {
                    let r = &self.running[i];
                    if r.is_ps { r.remaining <= EPS } else { r.end_time - self.now <= EPS }
                };
                if done {
                    finished.push(self.running.swap_remove(i));
                } else {
                    i += 1;
                }
            }
            for mut r in finished {
                // Release PS membership for the finished phase.
                if r.is_ps {
                    match r.res {
                        ResKind::Disk => self.disk_active[r.node as usize] -= 1,
                        ResKind::Nic => self.nic_active[r.node as usize] -= 1,
                    }
                }
                // A sibling copy may have won at this very event; this
                // copy is then moot — release its core and drop it.
                if self.stages[r.stage].done[r.task_idx] {
                    self.release_core(r.stage, r.node);
                    continue;
                }
                r.phase_idx += 1;
                let (stage, task_idx, node, started) = (r.stage, r.task_idx, r.node, r.started);
                let is_clone = r.is_clone;
                let mut meter = Meter::default();
                let entered = {
                    let st = &self.stages[stage];
                    let plan =
                        if is_clone { &st.clone_phases[task_idx] } else { &st.phases[task_idx] };
                    enter_phase(
                        self.cluster,
                        plan,
                        r,
                        self.now,
                        &mut self.disk_active,
                        &mut self.nic_active,
                        &mut meter,
                    )
                };
                self.apply_meter(stage, &meter);
                match entered {
                    Some(run) => self.running.push(run),
                    None => self.finish_task(stage, task_idx, node, started),
                }
            }
        }
    }

    /// Run every submitted stage to completion, returning completions in
    /// event order.
    pub fn drain(&mut self) -> Vec<StageCompletion> {
        let mut out = Vec::new();
        while let Some(c) = self.advance() {
            out.push(c);
        }
        out
    }

    // ---- internals ----

    fn apply_meter(&mut self, stage: StageHandle, meter: &Meter) {
        let st = &mut self.stages[stage];
        st.cpu_secs += meter.cpu_secs;
        st.disk_bytes += meter.disk_bytes;
        st.net_bytes += meter.net_bytes;
    }

    /// A copy released its core without finishing its task (moot or
    /// cancelled sibling of an already-won speculation race).
    fn release_core(&mut self, stage: StageHandle, node: NodeId) {
        self.free_cores[node as usize] += 1;
        self.admit_dirty = true;
        let job = self.stages[stage].job;
        self.jobs_running[job] -= 1;
    }

    /// The winning copy of `stage`'s task `task_idx` finished on `node`
    /// (started at `started`). Cancels the losing sibling, if any.
    fn finish_task(&mut self, stage: StageHandle, task_idx: usize, node: NodeId, started: f64) {
        self.free_cores[node as usize] += 1;
        self.admit_dirty = true;
        let job = self.stages[stage].job;
        self.jobs_running[job] -= 1;
        let overhead = self.cluster.task_overhead;
        let had_clone = {
            let st = &mut self.stages[stage];
            st.done[task_idx] = true;
            st.task_nodes[task_idx] = node;
            st.task_durations.push(self.now - started + overhead);
            st.unfinished -= 1;
            if st.unfinished == 0 {
                st.completion_due = Some(self.now + st.completion_overhead);
            }
            st.cloned[task_idx]
        };
        if had_clone {
            self.cancel_sibling(stage, task_idx);
        }
    }

    /// First-finisher-wins: cancel the still-running sibling copy of a
    /// speculated task — free its core, withdraw its processor-shared
    /// flow mid-stream, and refund the stage's meters for the work the
    /// loser never completed (phases it never entered were never metered).
    fn cancel_sibling(&mut self, stage: StageHandle, task_idx: usize) {
        let Some(j) =
            self.running.iter().position(|r| r.stage == stage && r.task_idx == task_idx)
        else {
            return; // the sibling finished at this same event: handled as moot
        };
        let r = self.running.swap_remove(j);
        if r.is_ps {
            match r.res {
                ResKind::Disk => {
                    self.disk_active[r.node as usize] -= 1;
                    self.stages[stage].disk_bytes -= r.remaining.max(0.0);
                }
                ResKind::Nic => {
                    self.nic_active[r.node as usize] -= 1;
                    self.stages[stage].net_bytes -= r.remaining.max(0.0);
                }
            }
        } else if r.is_cpu {
            self.stages[stage].cpu_secs -= (r.end_time - self.now).max(0.0);
        }
        self.release_core(stage, r.node);
    }

    fn any_free_core(&self) -> bool {
        self.free_cores.iter().any(|&c| c > 0)
    }

    /// Emit the earliest stage completion that is due at the current
    /// clock (ties: lowest handle).
    fn pop_due_completion(&mut self) -> Option<StageCompletion> {
        let mut best: Option<(f64, StageHandle)> = None;
        for (h, s) in self.stages.iter().enumerate() {
            if s.emitted {
                continue;
            }
            if let Some(due) = s.completion_due {
                if due <= self.now + EPS && best.map(|(bd, _)| due < bd).unwrap_or(true) {
                    best = Some((due, h));
                }
            }
        }
        let (due, h) = best?;
        let st = &mut self.stages[h];
        st.emitted = true;
        let stats = StageStats {
            duration: due - st.submitted_at,
            task_time: Summary::from(std::mem::take(&mut st.task_durations)),
            cpu_secs: st.cpu_secs,
            disk_bytes: st.disk_bytes,
            net_bytes: st.net_bytes,
            tasks: st.phases.len(),
            locality_hits: st.locality_hits,
            speculated: st.speculated,
        };
        Some(StageCompletion {
            handle: h,
            job: st.job,
            at: due,
            stats,
            task_nodes: std::mem::take(&mut st.task_nodes),
        })
    }

    /// The stage's first admissible pending task under the current free
    /// cores: a task launches NODE_LOCAL when one of its preferred nodes
    /// has a free core; a task with no preference — or one whose stage's
    /// locality hold has expired — takes any free core (the caller
    /// guarantees one exists). Tasks still holding for busy local nodes
    /// are skipped: that is delay scheduling. Returns
    /// `(queue position, task index, Some(local node) | None for ANY)`.
    fn find_admissible(&self, st: &StageRt) -> Option<(usize, usize, Option<NodeId>)> {
        let nodes = self.free_cores.len();
        let expired = self.policy.locality_wait <= 0.0
            || self.now + EPS >= st.submitted_at + self.policy.locality_wait;
        for (pos, &ti) in st.pending.iter().enumerate() {
            let prefs = &st.preferred[ti];
            if let Some(&n) = prefs.iter().find(|&&n| self.free_cores[n as usize % nodes] > 0) {
                return Some((pos, ti, Some((n as usize % nodes) as NodeId)));
            }
            if prefs.is_empty() || expired {
                return Some((pos, ti, None));
            }
        }
        None
    }

    /// Fill free cores from pending stages, in scheduler order, honoring
    /// per-task locality (delay scheduling).
    fn admit(&mut self) {
        if !self.admit_dirty {
            return;
        }
        self.admit_dirty = false;
        loop {
            if !self.any_free_core() {
                break;
            }
            // Per-stage admissible picks under the current free cores and
            // locality state.
            let mut candidates: Vec<StageView> = Vec::new();
            let mut picks: Vec<(usize, usize, Option<NodeId>)> = Vec::new();
            for (h, s) in self.stages.iter().enumerate() {
                if s.pending.is_empty() {
                    continue;
                }
                let Some(pick) = self.find_admissible(s) else { continue };
                let pool = self.pools.get(s.job).copied().unwrap_or_default();
                candidates.push(StageView {
                    handle: h,
                    job: s.job,
                    seq: s.seq,
                    pending: s.pending.len(),
                    job_running: self.jobs_running[s.job],
                    weight: pool.weight,
                    min_share: pool.min_share,
                });
                picks.push(pick);
            }
            if candidates.is_empty() {
                break;
            }
            let Some(h) = self.scheduler.pick(&candidates) else {
                break;
            };
            let ci = candidates
                .iter()
                .position(|c| c.handle == h)
                .expect("scheduler picked a non-candidate stage");
            let (pos, ti, local) = picks[ci];
            {
                let st = &mut self.stages[h];
                let removed = st.pending.remove(pos).expect("pick position is valid");
                debug_assert_eq!(removed, ti);
                if !st.preferred[ti].is_empty() {
                    st.pending_pref -= 1;
                }
            }
            let (node, is_local) = match local {
                Some(n) => (n, true),
                None => (self.pick_node_any(), false),
            };
            if is_local {
                self.stages[h].locality_hits += 1;
            }
            self.free_cores[node as usize] -= 1;
            self.jobs_running[self.stages[h].job] += 1;
            let r = Running {
                stage: h,
                task_idx: ti,
                node,
                phase_idx: 0,
                remaining: 0.0,
                end_time: 0.0,
                is_ps: false,
                res: ResKind::Disk,
                started: self.now,
                rate: 0.0,
                is_cpu: false,
                is_clone: false,
            };
            let mut meter = Meter::default();
            let entered = {
                let st = &self.stages[h];
                enter_phase(
                    self.cluster,
                    &st.phases[ti],
                    r,
                    self.now,
                    &mut self.disk_active,
                    &mut self.nic_active,
                    &mut meter,
                )
            };
            self.apply_meter(h, &meter);
            match entered {
                Some(run) => self.running.push(run),
                None => self.finish_task(h, ti, node, self.now), // zero-work task
            }
        }
    }

    /// Launch backup copies of stragglers: for every stage past its
    /// speculation quantile, any running original whose elapsed time
    /// exceeds multiplier × the median successful duration is cloned onto
    /// a *different* node (first finisher wins; see `cancel_sibling`).
    /// At most one backup per task.
    fn speculate(&mut self) {
        let Some(spec) = self.policy.speculation else { return };
        if !self.any_free_core() {
            return;
        }
        let overhead = self.cluster.task_overhead;
        let mut memo: Vec<Option<Option<f64>>> = vec![None; self.stages.len()];
        let mut cands: Vec<(StageHandle, usize, NodeId)> = Vec::new();
        for r in &self.running {
            if r.is_clone {
                continue;
            }
            let st = &self.stages[r.stage];
            if st.done[r.task_idx] || st.cloned[r.task_idx] {
                continue;
            }
            let th = *memo[r.stage].get_or_insert_with(|| spec_threshold(st, &spec));
            let Some(th) = th else { continue };
            if self.now - r.started + overhead >= th - EPS {
                cands.push((r.stage, r.task_idx, r.node));
            }
        }
        cands.sort_unstable();
        for (h, ti, orig) in cands {
            // A backup must land on a different machine than the copy it
            // races; if none has a free core, retry at a later event.
            let Some(node) = self.pick_node_excluding(orig) else { continue };
            self.free_cores[node as usize] -= 1;
            self.jobs_running[self.stages[h].job] += 1;
            {
                let st = &mut self.stages[h];
                st.cloned[ti] = true;
                st.speculated += 1;
            }
            let r = Running {
                stage: h,
                task_idx: ti,
                node,
                phase_idx: 0,
                remaining: 0.0,
                end_time: 0.0,
                is_ps: false,
                res: ResKind::Disk,
                started: self.now,
                rate: 0.0,
                is_cpu: false,
                is_clone: true,
            };
            let mut meter = Meter::default();
            let entered = {
                let st = &self.stages[h];
                enter_phase(
                    self.cluster,
                    &st.clone_phases[ti],
                    r,
                    self.now,
                    &mut self.disk_active,
                    &mut self.nic_active,
                    &mut meter,
                )
            };
            self.apply_meter(h, &meter);
            match entered {
                Some(run) => self.running.push(run),
                None => self.finish_task(h, ti, node, self.now), // zero-work clone wins
            }
            if !self.any_free_core() {
                break;
            }
        }
    }

    /// Round-robin scan for any free core. Call only when one exists.
    fn pick_node_any(&mut self) -> NodeId {
        let nodes = self.free_cores.len();
        for k in 0..nodes {
            let cand = (self.rr + k) % nodes;
            if self.free_cores[cand] > 0 {
                self.rr = (cand + 1) % nodes;
                return cand as NodeId;
            }
        }
        unreachable!("pick_node_any called with no free core")
    }

    /// Round-robin scan for a free core on any node other than `exclude`
    /// (speculative copies must race from a different machine).
    fn pick_node_excluding(&mut self, exclude: NodeId) -> Option<NodeId> {
        let nodes = self.free_cores.len();
        for k in 0..nodes {
            let cand = (self.rr + k) % nodes;
            if cand as NodeId != exclude && self.free_cores[cand] > 0 {
                self.rr = (cand + 1) % nodes;
                return Some(cand as NodeId);
            }
        }
        None
    }
}

/// Scale the CPU phases of a task's plan by `factor` (jitter and the
/// straggler tail apply to compute, not to I/O volumes — bytes moved are
/// a property of the data, not of the executor's health).
fn scale_cpu(phases: &[Phase], factor: f64) -> Vec<Phase> {
    phases
        .iter()
        .map(|p| match *p {
            Phase::Cpu { secs } => Phase::Cpu { secs: secs * factor },
            other => other,
        })
        .collect()
}

/// The stage's speculation threshold: `multiplier × median successful
/// duration`, or `None` while fewer than `quantile` of its tasks are
/// done (Spark's `minFinishedForSpeculation`).
fn spec_threshold(st: &StageRt, spec: &SpecPolicy) -> Option<f64> {
    let n = st.phases.len();
    if n == 0 || st.clone_phases.is_empty() {
        return None;
    }
    let done = n - st.unfinished;
    let min_done = ((spec.quantile * n as f64).ceil() as usize).max(1);
    if done < min_done {
        return None;
    }
    Some(spec.multiplier * median(&st.task_durations))
}

/// Upper median (Spark's `durations(medianIndex)`); `xs` must be
/// non-empty.
fn median(xs: &[f64]) -> f64 {
    debug_assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal));
    v[v.len() / 2]
}

/// Start the task's next non-noop phase (or return `None` when all
/// phases are done). NaN-valued phases are treated as noops — see
/// [`Phase::is_noop`].
fn enter_phase(
    cluster: &ClusterSpec,
    phases: &[Phase],
    mut r: Running,
    now: f64,
    disk_active: &mut [u32],
    nic_active: &mut [u32],
    meter: &mut Meter,
) -> Option<Running> {
    loop {
        let Some(p) = phases.get(r.phase_idx) else {
            return None; // all phases done
        };
        if p.is_noop() {
            r.phase_idx += 1;
            continue;
        }
        match *p {
            Phase::Cpu { secs } => {
                let d = secs / cluster.cpu_speed;
                meter.cpu_secs += d;
                r.is_ps = false;
                r.is_cpu = true;
                r.end_time = now + d;
            }
            Phase::Fixed { secs } => {
                r.is_ps = false;
                r.is_cpu = false;
                r.end_time = now + secs;
            }
            Phase::DiskRead { bytes } | Phase::DiskWrite { bytes } => {
                meter.disk_bytes += bytes;
                r.is_ps = true;
                r.is_cpu = false;
                r.res = ResKind::Disk;
                r.remaining = bytes;
                disk_active[r.node as usize] += 1;
            }
            Phase::NetIn { bytes } => {
                meter.net_bytes += bytes;
                r.is_ps = true;
                r.is_cpu = false;
                r.res = ResKind::Nic;
                r.remaining = bytes;
                nic_active[r.node as usize] += 1;
            }
        }
        return Some(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet() -> ClusterSpec {
        let mut c = ClusterSpec::mini();
        c.task_overhead = 0.0;
        c
    }

    fn opts0() -> SimOpts {
        SimOpts { jitter: 0.0, seed: 1, straggler: None }
    }

    fn cpu_tasks(n: usize, secs: f64) -> Vec<TaskSpec> {
        (0..n).map(|_| TaskSpec::new(vec![Phase::Cpu { secs }])).collect()
    }

    #[test]
    fn two_stages_interleave_on_shared_cores() {
        // 8 cores; two stages of 8 × 1 s submitted together under FAIR:
        // each job gets 4 cores → both finish at t = 2.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.submit(0, &cpu_tasks(8, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(8, 1.0), &opts0());
        let done = sim.drain();
        assert_eq!(done.len(), 2);
        for d in &done {
            assert!((d.at - 2.0).abs() < 1e-9, "fair finish at {}", d.at);
        }
    }

    #[test]
    fn fifo_prioritizes_the_earlier_job() {
        // Same two stages under FIFO: job 0 takes all 8 cores and
        // finishes at t = 1; job 1 runs after, finishing at t = 2.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &cpu_tasks(8, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(8, 1.0), &opts0());
        let done = sim.drain();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].job, 0);
        assert!((done[0].at - 1.0).abs() < 1e-9, "{}", done[0].at);
        assert_eq!(done[1].job, 1);
        assert!((done[1].at - 2.0).abs() < 1e-9, "{}", done[1].at);
    }

    #[test]
    fn submission_mid_flight_shares_the_disk() {
        // Job 0 writes 100 MB alone on node 0 (disk 100 MB/s). Drain it,
        // then submit two concurrent writers on the same node: they share
        // the disk and take 2 s.
        let mut c = quiet();
        c.disk_bw = 100.0e6;
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &[TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0)], &opts0());
        let first = sim.advance().unwrap();
        assert!((first.at - 1.0).abs() < 1e-6);
        sim.submit(
            1,
            &[
                TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0),
                TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0),
            ],
            &opts0(),
        );
        let second = sim.advance().unwrap();
        assert!((second.at - 3.0).abs() < 1e-6, "{}", second.at);
        assert!(sim.advance().is_none());
    }

    #[test]
    fn completion_waits_for_wave_overhead() {
        let mut c = quiet();
        c.task_overhead = 0.5;
        // 16 tasks on 8 cores → 2 waves → completion at 2×1s + 2×0.5s.
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &cpu_tasks(16, 1.0), &opts0());
        let done = sim.advance().unwrap();
        assert!((done.at - 3.0).abs() < 1e-9, "{}", done.at);
        assert!((done.stats.duration - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stage_completes_immediately() {
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        let h = sim.submit(0, &[], &opts0());
        let done = sim.advance().unwrap();
        assert_eq!(done.handle, h);
        assert!(done.at < 1e-9);
        assert_eq!(done.stats.tasks, 0);
        assert!(done.task_nodes.is_empty());
        assert!(sim.advance().is_none());
    }

    #[test]
    fn scheduler_mode_parses() {
        assert_eq!(SchedulerMode::from_config_name("fifo"), Some(SchedulerMode::Fifo));
        assert_eq!(SchedulerMode::from_config_name("FAIR"), Some(SchedulerMode::Fair));
        assert_eq!(SchedulerMode::from_config_name("fair "), Some(SchedulerMode::Fair));
        assert_eq!(SchedulerMode::from_config_name("lottery"), None);
        assert_eq!(SchedulerMode::Fifo.config_name(), "FIFO");
        assert_eq!(scheduler_for(SchedulerMode::Fair).name(), "FAIR");
    }

    #[test]
    fn event_core_is_deterministic_across_runs() {
        let c = ClusterSpec::mini();
        let mk = || {
            let mut sim = EventSim::new(&c, Box::new(FairScheduler));
            for j in 0..3usize {
                let tasks: Vec<TaskSpec> = (0..10)
                    .map(|i| {
                        TaskSpec::new(vec![
                            Phase::Cpu { secs: 0.1 + (i % 3) as f64 * 0.05 },
                            Phase::DiskWrite { bytes: 2e6 },
                            Phase::NetIn { bytes: 1e6 },
                        ])
                    })
                    .collect();
                sim.submit(
                    j,
                    &tasks,
                    &SimOpts { jitter: 0.08, seed: 7 + j as u64, straggler: None },
                );
            }
            sim.drain().iter().map(|d| (d.handle, d.at)).collect::<Vec<_>>()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "event core must reproduce bit-identically");
    }

    #[test]
    fn nan_phases_are_noops() {
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(
            0,
            &[TaskSpec::new(vec![
                Phase::Cpu { secs: f64::NAN },
                Phase::DiskRead { bytes: f64::NAN },
                Phase::Cpu { secs: 1.0 },
            ])],
            &opts0(),
        );
        let done = sim.advance().unwrap();
        assert!(done.at.is_finite(), "NaN phases must not poison the clock");
        assert!((done.at - 1.0).abs() < 1e-9, "{}", done.at);
    }

    // ---- task-granular features: delay scheduling ----

    #[test]
    fn delay_scheduling_holds_then_degrades() {
        // 3 × 1 s CPU tasks all preferring node 0 (2 cores). Two run
        // locally at t=0; the third:
        //   wait=0   → degrades immediately, runs remotely, stage = 1.0 s
        //   wait=0.5 → holds 0.5 s, then runs remotely, stage = 1.5 s
        //   wait=2   → holds until a local core frees at t=1, stage = 2.0 s
        let c = quiet();
        let run_with = |wait: f64| {
            let mut sim = EventSim::with_policy(
                &c,
                Box::new(FifoScheduler),
                SimPolicy { locality_wait: wait, speculation: None },
            );
            let tasks: Vec<TaskSpec> =
                (0..3).map(|_| TaskSpec::new(vec![Phase::Cpu { secs: 1.0 }]).on(0)).collect();
            sim.submit(0, &tasks, &opts0());
            let done = sim.advance().unwrap();
            assert!(sim.advance().is_none());
            (done.at, done.stats.locality_hits)
        };
        let (t0, h0) = run_with(0.0);
        assert!((t0 - 1.0).abs() < 1e-9, "wait=0 must not hold: {t0}");
        assert_eq!(h0, 2);
        let (t1, h1) = run_with(0.5);
        assert!((t1 - 1.5).abs() < 1e-9, "held 0.5 s then ran remotely: {t1}");
        assert_eq!(h1, 2);
        let (t2, h2) = run_with(2.0);
        assert!((t2 - 2.0).abs() < 1e-9, "patient wait keeps the task local: {t2}");
        assert_eq!(h2, 3, "all three tasks NODE_LOCAL under a patient wait");
    }

    #[test]
    fn held_stage_cedes_cores_to_other_jobs() {
        // Job 0 hogs node 0; job 1's task holds for node 0 under a long
        // locality wait, so job 2's preference-free task must take the
        // idle node-1 core instead of queuing behind job 1's FIFO
        // priority — the point of delay scheduling.
        let mut c = quiet();
        c.nodes = 2;
        c.cores_per_node = 1;
        let mut sim = EventSim::with_policy(
            &c,
            Box::new(FifoScheduler),
            SimPolicy { locality_wait: 10.0, speculation: None },
        );
        sim.submit(0, &[TaskSpec::new(vec![Phase::Cpu { secs: 5.0 }]).on(0)], &opts0());
        sim.submit(1, &[TaskSpec::new(vec![Phase::Cpu { secs: 1.0 }]).on(0)], &opts0());
        sim.submit(2, &[TaskSpec::new(vec![Phase::Cpu { secs: 1.0 }])], &opts0());
        let done = sim.drain();
        let j2 = done.iter().find(|d| d.job == 2).unwrap();
        assert!((j2.at - 1.0).abs() < 1e-9, "job 2 must take the idle node at t=0: {}", j2.at);
        let j0 = done.iter().find(|d| d.job == 0).unwrap();
        assert!((j0.at - 5.0).abs() < 1e-9, "{}", j0.at);
        let j1 = done.iter().find(|d| d.job == 1).unwrap();
        assert!((j1.at - 6.0).abs() < 1e-9, "job 1 holds for its local core: {}", j1.at);
        assert_eq!(j1.stats.locality_hits, 1, "the held task launches NODE_LOCAL");
    }

    // ---- task-granular features: speculative execution ----

    #[test]
    fn speculative_copy_escapes_a_contended_disk() {
        // Node 0's disk (100 MB/s) is hogged by a 1 GB reader (job 1).
        // Job 0 has a quick CPU task and a 100 MB read pinned to node 0.
        // Without speculation the read shares the disk at 50 MB/s and
        // takes 2 s; with speculation a backup copy launches on another
        // node at t=0.2 (median 0.1 s × multiplier 2), reads alone at
        // 100 MB/s, and wins at t=1.2. The loser's flow is cancelled, so
        // the hog accelerates (10.6 s vs 11.0 s) and job 0's disk meter
        // is refunded for the 40 MB the loser never read.
        let mut c = quiet();
        c.disk_bw = 100.0e6;
        let run_with = |spec_on: bool| {
            let policy = SimPolicy {
                locality_wait: 0.0,
                speculation: spec_on
                    .then_some(SpecPolicy { quantile: 0.5, multiplier: 2.0 }),
            };
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            sim.submit(
                1,
                &[TaskSpec::new(vec![Phase::DiskRead { bytes: 1000e6 }]).on(0)],
                &opts0(),
            );
            sim.submit(
                0,
                &[
                    TaskSpec::new(vec![Phase::Cpu { secs: 0.1 }]).on(1),
                    TaskSpec::new(vec![Phase::DiskRead { bytes: 100e6 }]).on(0),
                ],
                &opts0(),
            );
            sim.drain()
        };

        let off = run_with(false);
        let off0 = off.iter().find(|d| d.job == 0).unwrap();
        let off1 = off.iter().find(|d| d.job == 1).unwrap();
        assert!((off0.at - 2.0).abs() < 1e-6, "shared read: {}", off0.at);
        assert!((off1.at - 11.0).abs() < 1e-6, "hog without cancel: {}", off1.at);
        assert_eq!(off0.stats.speculated, 0);

        let on = run_with(true);
        let on0 = on.iter().find(|d| d.job == 0).unwrap();
        let on1 = on.iter().find(|d| d.job == 1).unwrap();
        assert!((on0.at - 1.2).abs() < 1e-6, "backup copy wins at 1.2 s: {}", on0.at);
        assert_eq!(on0.stats.speculated, 1);
        assert!((on1.at - 10.6).abs() < 1e-6, "hog accelerates after cancel: {}", on1.at);
        // Meter refund: 100 MB original − 40 MB never read + 100 MB clone.
        assert!(
            (on0.stats.disk_bytes - 160e6).abs() < 1.0,
            "loser's unread bytes refunded: {}",
            on0.stats.disk_bytes
        );
        // The winning copy's node is recorded for locality parentage.
        assert_ne!(on0.task_nodes[1], 0, "winner ran off node 0");
    }

    #[test]
    fn speculation_is_a_noop_without_stragglers() {
        // Healthy cluster, ±4 % jitter: no task exceeds 1.5 × median, so
        // enabling speculation changes nothing — same clock, no clones.
        let c = ClusterSpec::mini();
        let opts = SimOpts { jitter: 0.04, seed: 42, straggler: None };
        let mk = |policy: SimPolicy| {
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            sim.submit(0, &cpu_tasks(16, 1.0), &opts);
            let done = sim.advance().unwrap();
            (done.at, done.stats.speculated)
        };
        let (off, _) = mk(SimPolicy::default());
        let (on, clones) = mk(SimPolicy {
            locality_wait: 0.0,
            speculation: Some(SpecPolicy { quantile: 0.75, multiplier: 1.5 }),
        });
        assert_eq!(clones, 0);
        assert!((on - off).abs() < 1e-12, "speculation must be free on a healthy stage");
    }

    #[test]
    fn straggler_tail_triggers_clones_and_recovers() {
        // All-straggler probability on one task out of 16: prob high
        // enough that the tail exists, speculation on → the stage must
        // beat the speculation-off run and launch at least one clone.
        let c = quiet();
        let opts = SimOpts {
            jitter: 0.02,
            seed: 7,
            straggler: Some(super::super::Straggler { prob: 0.5, factor: 10.0 }),
        };
        // A low quantile so healthy finishers unlock speculation even
        // when around half the tasks straggle.
        let mk = |spec: Option<SpecPolicy>| {
            let mut sim = EventSim::with_policy(
                &c,
                Box::new(FifoScheduler),
                SimPolicy { locality_wait: 0.0, speculation: spec },
            );
            sim.submit(0, &cpu_tasks(16, 1.0), &opts);
            let done = sim.advance().unwrap();
            (done.at, done.stats.speculated)
        };
        let (off, _) = mk(None);
        let (on, clones) = mk(Some(SpecPolicy { quantile: 0.12, multiplier: 1.5 }));
        assert!(clones > 0, "stragglers must be speculated");
        assert!(
            on < off * 0.6,
            "speculation must recover the straggler tail: on {on:.2}s vs off {off:.2}s"
        );
        // Determinism: repeat bit-identically.
        let (on2, clones2) = mk(Some(SpecPolicy { quantile: 0.12, multiplier: 1.5 }));
        assert_eq!(on, on2);
        assert_eq!(clones, clones2);
    }

    // ---- task-granular features: weighted FAIR pools ----

    #[test]
    fn fair_pools_honor_weight() {
        // 8 cores, 16 × 1 s tasks per job; weight 3 vs 1 → 6/2 core
        // split → weighted job at t=3, the other at t=4 (hand-traced).
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.set_pool(0, PoolSpec { weight: 3.0, min_share: 0 });
        sim.submit(0, &cpu_tasks(16, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(16, 1.0), &opts0());
        let done = sim.drain();
        let j0 = done.iter().find(|d| d.job == 0).unwrap().at;
        let j1 = done.iter().find(|d| d.job == 1).unwrap().at;
        assert!((j0 - 3.0).abs() < 1e-9, "weight-3 pool finishes at {j0}");
        assert!((j1 - 4.0).abs() < 1e-9, "weight-1 pool finishes at {j1}");
    }

    #[test]
    fn fair_pools_honor_min_share() {
        // Job 1 holds minShare 6 of the 8 cores: it is "needy" until it
        // runs 6 tasks, mirroring the weight trace → j1 at t=3, j0 at t=4.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.set_pool(1, PoolSpec { weight: 1.0, min_share: 6 });
        sim.submit(0, &cpu_tasks(16, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(16, 1.0), &opts0());
        let done = sim.drain();
        let j0 = done.iter().find(|d| d.job == 0).unwrap().at;
        let j1 = done.iter().find(|d| d.job == 1).unwrap().at;
        assert!((j1 - 3.0).abs() < 1e-9, "minShare-6 pool finishes at {j1}");
        assert!((j0 - 4.0).abs() < 1e-9, "default pool finishes at {j0}");
    }

    #[test]
    fn default_pools_reduce_to_even_shares() {
        // Without explicit pools the weighted comparator must reproduce
        // fewest-running-first: two identical jobs split 4/4 and tie.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.submit(0, &cpu_tasks(8, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(8, 1.0), &opts0());
        for d in sim.drain() {
            assert!((d.at - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn task_granular_features_compose_deterministically() {
        // Locality wait + speculation + stragglers + FAIR pools, three
        // jobs: two runs must agree bit for bit.
        let c = ClusterSpec::mini();
        let mk = || {
            let mut sim = EventSim::with_policy(
                &c,
                Box::new(FairScheduler),
                SimPolicy {
                    locality_wait: 0.3,
                    speculation: Some(SpecPolicy { quantile: 0.6, multiplier: 1.3 }),
                },
            );
            sim.set_pool(1, PoolSpec { weight: 2.0, min_share: 2 });
            for j in 0..3usize {
                let tasks: Vec<TaskSpec> = (0..12)
                    .map(|i| {
                        TaskSpec::new(vec![
                            Phase::Cpu { secs: 0.2 + (i % 4) as f64 * 0.03 },
                            Phase::DiskWrite { bytes: 3e6 },
                        ])
                        .on((i % 4) as NodeId)
                    })
                    .collect();
                sim.submit(
                    j,
                    &tasks,
                    &SimOpts {
                        jitter: 0.05,
                        seed: 11 + j as u64,
                        straggler: Some(super::super::Straggler { prob: 0.2, factor: 6.0 }),
                    },
                );
            }
            sim.drain()
                .iter()
                .map(|d| (d.handle, d.at, d.stats.speculated, d.stats.locality_hits))
                .collect::<Vec<_>>()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "composed features must reproduce bit-identically");
    }
}
