//! The persistent event core: a single global event queue scheduling
//! tasks from **many stages of many jobs at once** over the modeled
//! cluster.
//!
//! [`EventSim`] owns the cluster's contended state — per-node core slots
//! and the processor-shared disk/NIC flow sets — for the whole lifetime
//! of a simulation. Stages are [`submit`](EventSim::submit)ted as they
//! become runnable (the engine submits a stage the moment its DAG
//! parents complete) and the core interleaves their tasks freely: a
//! reduce stage of job A shares disks and NICs with a map stage of job B
//! at fair fluid-flow rates, exactly as concurrent Spark jobs contend on
//! one cluster.
//!
//! **Which** pending task gets a freed core is delegated to a pluggable
//! [`Scheduler`] — the analogue of Spark's `spark.scheduler.mode`:
//!
//! * [`FifoScheduler`] — earlier-submitted jobs win; within a job,
//!   earlier-submitted stages win (Spark's default FIFO pool ordering by
//!   job submission time).
//! * [`FairScheduler`] — the job with the fewest currently running tasks
//!   wins (the even-share steady state of Spark's fair scheduler pools).
//!
//! Time only moves at events (task phase completions and stage
//! completion barriers); between events every processor-shared flow
//! progresses at its cached fair-share rate — the standard fluid-flow
//! DES. Everything is deterministic in `(submission order, SimOpts
//! seed)`: repeated runs produce bit-identical clocks.
//!
//! A stage *completes* `waves × task_overhead` after its last task
//! finishes (the per-wave scheduling/launch overhead the barrier model
//! charged at stage granularity); its [`StageCompletion`] is surfaced to
//! the driver from [`advance`](EventSim::advance), which is the hook the
//! engine uses to unlock DAG children.

use super::{Phase, SimOpts, StageStats, TaskSpec};
use crate::cluster::{ClusterSpec, NodeId};
use crate::util::stats::Summary;
use crate::util::Prng;
use std::collections::VecDeque;
use std::fmt;

/// Identifies one submitting job within an [`EventSim`] (the engine uses
/// the job's index in the submission batch).
pub type JobId = usize;

/// Handle for a submitted stage, unique within one [`EventSim`].
pub type StageHandle = usize;

/// `spark.scheduler.mode` — how concurrently runnable tasks from
/// different jobs are ordered onto free cores.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SchedulerMode {
    /// Jobs get cores in submission order (Spark's default).
    #[default]
    Fifo,
    /// Running-task counts are balanced across jobs.
    Fair,
}

impl SchedulerMode {
    pub const ALL: [SchedulerMode; 2] = [SchedulerMode::Fifo, SchedulerMode::Fair];

    pub fn config_name(self) -> &'static str {
        match self {
            SchedulerMode::Fifo => "FIFO",
            SchedulerMode::Fair => "FAIR",
        }
    }

    pub fn from_config_name(s: &str) -> Option<SchedulerMode> {
        match s.trim().to_ascii_uppercase().as_str() {
            "FIFO" => Some(SchedulerMode::Fifo),
            "FAIR" => Some(SchedulerMode::Fair),
            _ => None,
        }
    }
}

impl fmt::Display for SchedulerMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.config_name())
    }
}

/// What a [`Scheduler`] sees of one runnable stage when picking the next
/// task to admit.
#[derive(Clone, Copy, Debug)]
pub struct StageView {
    /// Handle of the stage (return this from [`Scheduler::pick`]).
    pub handle: StageHandle,
    /// Submitting job.
    pub job: JobId,
    /// Global submission sequence number of the stage.
    pub seq: usize,
    /// Tasks of this stage still waiting for a core.
    pub pending: usize,
    /// Tasks of this stage's *job* currently holding cores.
    pub job_running: usize,
}

/// Task-admission policy: given the stages that currently have pending
/// tasks, choose the stage whose next task gets the free core.
///
/// Implementations must be deterministic functions of the view (the
/// event core's reproducibility guarantee depends on it).
pub trait Scheduler {
    /// Policy name for reports.
    fn name(&self) -> &'static str;

    /// Pick a stage from `candidates` (all have `pending > 0`; the slice
    /// is ordered by handle). Returning `None` leaves the cores idle
    /// until the next submission.
    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle>;
}

/// FIFO: lowest job id first (jobs are numbered in submission order),
/// then lowest stage submission sequence.
#[derive(Clone, Copy, Debug, Default)]
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle> {
        candidates.iter().min_by_key(|s| (s.job, s.seq)).map(|s| s.handle)
    }
}

/// FAIR: the job with the fewest running tasks first (ties: lowest job
/// id, then submission sequence) — jobs converge to even core shares.
#[derive(Clone, Copy, Debug, Default)]
pub struct FairScheduler;

impl Scheduler for FairScheduler {
    fn name(&self) -> &'static str {
        "FAIR"
    }

    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle> {
        candidates.iter().min_by_key(|s| (s.job_running, s.job, s.seq)).map(|s| s.handle)
    }
}

/// Instantiate the scheduler for a mode.
pub fn scheduler_for(mode: SchedulerMode) -> Box<dyn Scheduler> {
    match mode {
        SchedulerMode::Fifo => Box::new(FifoScheduler),
        SchedulerMode::Fair => Box::new(FairScheduler),
    }
}

/// Emitted by [`EventSim::advance`] when a submitted stage has fully
/// finished (all tasks done + the stage's wave overhead elapsed).
#[derive(Clone, Debug)]
pub struct StageCompletion {
    pub handle: StageHandle,
    pub job: JobId,
    /// Event-clock time of the completion.
    pub at: f64,
    pub stats: StageStats,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ResKind {
    Disk,
    Nic,
}

/// Per-task run state.
struct Running {
    stage: StageHandle,
    task_idx: usize,
    node: NodeId,
    phase_idx: usize,
    /// For PS phases: remaining bytes.
    remaining: f64,
    /// For fixed-rate phases: absolute end time.
    end_time: f64,
    is_ps: bool,
    res: ResKind,
    started: f64,
    /// Rate computed during the event scan, reused by the advance pass
    /// (rates only change at events).
    rate: f64,
}

/// Resource metering accumulated while a task enters phases.
#[derive(Default)]
struct Meter {
    cpu_secs: f64,
    disk_bytes: f64,
    net_bytes: f64,
}

/// Per-stage runtime state inside the core.
struct StageRt {
    job: JobId,
    seq: usize,
    /// Jittered phase lists, one per task.
    phases: Vec<Vec<Phase>>,
    preferred: Vec<Option<NodeId>>,
    pending: VecDeque<usize>,
    /// Tasks not yet finished.
    unfinished: usize,
    submitted_at: f64,
    task_durations: Vec<f64>,
    cpu_secs: f64,
    disk_bytes: f64,
    net_bytes: f64,
    /// `waves × task_overhead`, charged between the last task finish and
    /// the stage's completion event.
    completion_overhead: f64,
    /// Absolute completion time, set when `unfinished` reaches zero.
    completion_due: Option<f64>,
    /// The completion event has been surfaced to the driver.
    emitted: bool,
}

/// The persistent, multi-stage, multi-job discrete-event simulator core
/// (see module docs).
pub struct EventSim<'a> {
    cluster: &'a ClusterSpec,
    scheduler: Box<dyn Scheduler>,
    now: f64,
    free_cores: Vec<i64>,
    disk_active: Vec<u32>,
    nic_active: Vec<u32>,
    running: Vec<Running>,
    stages: Vec<StageRt>,
    /// Running task count per job (indexed by `JobId`).
    jobs_running: Vec<usize>,
    /// Round-robin cursor for locality-free placement.
    rr: usize,
    /// Admission gate: only rescan pending work when cores were freed (or
    /// stages submitted) since the last pass.
    cores_freed: bool,
}

const EPS: f64 = 1e-9;

impl<'a> EventSim<'a> {
    pub fn new(cluster: &'a ClusterSpec, scheduler: Box<dyn Scheduler>) -> EventSim<'a> {
        let nodes = cluster.nodes as usize;
        EventSim {
            cluster,
            scheduler,
            now: 0.0,
            free_cores: vec![cluster.cores_per_node as i64; nodes],
            disk_active: vec![0u32; nodes],
            nic_active: vec![0u32; nodes],
            running: Vec::with_capacity(cluster.total_cores() as usize),
            stages: Vec::new(),
            jobs_running: Vec::new(),
            rr: 0,
            cores_freed: false,
        }
    }

    /// Current event-clock time (seconds, simulated).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// The scheduling policy in force.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// Submit a stage of `tasks` on behalf of `job`. CPU jitter is drawn
    /// per task, in task order, from a stream seeded by `opts.seed` —
    /// identical to the historical per-stage barrier runner, so a linear
    /// DAG under FIFO reproduces the barrier path bit for bit.
    pub fn submit(&mut self, job: JobId, tasks: &[TaskSpec], opts: &SimOpts) -> StageHandle {
        let mut rng = Prng::new(opts.seed ^ 0xD15C0);
        let phases: Vec<Vec<Phase>> = tasks
            .iter()
            .map(|t| {
                let factor = 1.0 + opts.jitter * (rng.f64() - 0.5) * 2.0;
                t.phases
                    .iter()
                    .map(|p| match *p {
                        Phase::Cpu { secs } => Phase::Cpu { secs: secs * factor },
                        other => other,
                    })
                    .collect()
            })
            .collect();
        let preferred: Vec<Option<NodeId>> = tasks.iter().map(|t| t.preferred_node).collect();

        // One wave overhead per `total_cores` tasks, charged between the
        // last task finish and the completion event (the engine's
        // downstream stages unlock only then).
        let waves =
            (tasks.len() as f64 / self.cluster.total_cores() as f64).ceil().max(1.0);
        let completion_overhead = waves * self.cluster.task_overhead;

        let handle = self.stages.len();
        let n = tasks.len();
        if job >= self.jobs_running.len() {
            self.jobs_running.resize(job + 1, 0);
        }
        self.stages.push(StageRt {
            job,
            seq: handle,
            phases,
            preferred,
            pending: (0..n).collect(),
            unfinished: n,
            submitted_at: self.now,
            task_durations: Vec::with_capacity(n),
            cpu_secs: 0.0,
            disk_bytes: 0.0,
            net_bytes: 0.0,
            completion_overhead,
            completion_due: if n == 0 { Some(self.now + completion_overhead) } else { None },
            emitted: false,
        });
        self.cores_freed = true;
        handle
    }

    /// Advance the clock until the next stage completes; `None` once all
    /// submitted stages have completed (the sim stays usable — submit
    /// more and call again).
    pub fn advance(&mut self) -> Option<StageCompletion> {
        loop {
            if let Some(c) = self.pop_due_completion() {
                return Some(c);
            }
            self.admit();

            // ---- Find the next event (task phase end or stage
            // completion barrier), caching PS fair-share rates ----
            let mut dt = f64::INFINITY;
            for r in &mut self.running {
                let t = if r.is_ps {
                    let active = match r.res {
                        ResKind::Disk => self.disk_active[r.node as usize],
                        ResKind::Nic => self.nic_active[r.node as usize],
                    } as f64;
                    let cap = match r.res {
                        ResKind::Disk => self.cluster.disk_bw,
                        ResKind::Nic => self.cluster.net_bw,
                    };
                    r.rate = cap / active.max(1.0);
                    r.remaining / r.rate
                } else {
                    r.end_time - self.now
                };
                if t < dt {
                    dt = t;
                }
            }
            for s in &self.stages {
                if let Some(due) = s.completion_due {
                    if !s.emitted {
                        let t = due - self.now;
                        if t < dt {
                            dt = t;
                        }
                    }
                }
            }
            if dt == f64::INFINITY {
                debug_assert!(self.running.is_empty());
                return None; // fully idle
            }
            let dt = dt.max(0.0);
            self.now += dt;

            // ---- Advance all active flows by dt (cached pre-event
            // rates), then extract completions, then start successor
            // phases. Three separate passes so a phase that starts at
            // this event is never credited progress for the interval that
            // just elapsed. ----
            for r in &mut self.running {
                if r.is_ps {
                    r.remaining -= r.rate * dt;
                }
            }
            let mut finished: Vec<Running> = Vec::new();
            let mut i = 0;
            while i < self.running.len() {
                let done = {
                    let r = &self.running[i];
                    if r.is_ps { r.remaining <= EPS } else { r.end_time - self.now <= EPS }
                };
                if done {
                    finished.push(self.running.swap_remove(i));
                } else {
                    i += 1;
                }
            }
            for mut r in finished {
                // Release PS membership for the finished phase.
                if r.is_ps {
                    match r.res {
                        ResKind::Disk => self.disk_active[r.node as usize] -= 1,
                        ResKind::Nic => self.nic_active[r.node as usize] -= 1,
                    }
                }
                r.phase_idx += 1;
                let (stage, node, started) = (r.stage, r.node, r.started);
                let mut meter = Meter::default();
                let entered = {
                    let st = &self.stages[stage];
                    enter_phase(
                        self.cluster,
                        &st.phases[r.task_idx],
                        r,
                        self.now,
                        &mut self.disk_active,
                        &mut self.nic_active,
                        &mut meter,
                    )
                };
                self.apply_meter(stage, &meter);
                match entered {
                    Some(run) => self.running.push(run),
                    None => self.finish_task(stage, node, started),
                }
            }
        }
    }

    /// Run every submitted stage to completion, returning completions in
    /// event order.
    pub fn drain(&mut self) -> Vec<StageCompletion> {
        let mut out = Vec::new();
        while let Some(c) = self.advance() {
            out.push(c);
        }
        out
    }

    // ---- internals ----

    fn apply_meter(&mut self, stage: StageHandle, meter: &Meter) {
        let st = &mut self.stages[stage];
        st.cpu_secs += meter.cpu_secs;
        st.disk_bytes += meter.disk_bytes;
        st.net_bytes += meter.net_bytes;
    }

    /// A task of `stage` finished on `node` (started at `started`).
    fn finish_task(&mut self, stage: StageHandle, node: NodeId, started: f64) {
        self.free_cores[node as usize] += 1;
        self.cores_freed = true;
        let job = self.stages[stage].job;
        self.jobs_running[job] -= 1;
        let st = &mut self.stages[stage];
        st.task_durations.push(self.now - started + self.cluster.task_overhead);
        st.unfinished -= 1;
        if st.unfinished == 0 {
            st.completion_due = Some(self.now + st.completion_overhead);
        }
    }

    fn any_free_core(&self) -> bool {
        self.free_cores.iter().any(|&c| c > 0)
    }

    /// Emit the earliest stage completion that is due at the current
    /// clock (ties: lowest handle).
    fn pop_due_completion(&mut self) -> Option<StageCompletion> {
        let mut best: Option<(f64, StageHandle)> = None;
        for (h, s) in self.stages.iter().enumerate() {
            if s.emitted {
                continue;
            }
            if let Some(due) = s.completion_due {
                if due <= self.now + EPS && best.map(|(bd, _)| due < bd).unwrap_or(true) {
                    best = Some((due, h));
                }
            }
        }
        let (due, h) = best?;
        let st = &mut self.stages[h];
        st.emitted = true;
        let stats = StageStats {
            duration: due - st.submitted_at,
            task_time: Summary::from(std::mem::take(&mut st.task_durations)),
            cpu_secs: st.cpu_secs,
            disk_bytes: st.disk_bytes,
            net_bytes: st.net_bytes,
            tasks: st.phases.len(),
        };
        Some(StageCompletion { handle: h, job: st.job, at: due, stats })
    }

    /// Fill free cores from pending stages, in scheduler order.
    fn admit(&mut self) {
        if !self.cores_freed {
            return;
        }
        self.cores_freed = false;
        loop {
            if !self.any_free_core() {
                break;
            }
            let candidates: Vec<StageView> = self
                .stages
                .iter()
                .enumerate()
                .filter(|(_, s)| !s.pending.is_empty())
                .map(|(h, s)| StageView {
                    handle: h,
                    job: s.job,
                    seq: s.seq,
                    pending: s.pending.len(),
                    job_running: self.jobs_running[s.job],
                })
                .collect();
            if candidates.is_empty() {
                break;
            }
            let Some(h) = self.scheduler.pick(&candidates) else {
                break;
            };
            debug_assert!(!self.stages[h].pending.is_empty(), "scheduler picked an idle stage");
            let ti = self.stages[h].pending.pop_front().expect("candidate has pending tasks");
            let node = self.pick_node(self.stages[h].preferred[ti]);
            self.free_cores[node as usize] -= 1;
            self.jobs_running[self.stages[h].job] += 1;
            let r = Running {
                stage: h,
                task_idx: ti,
                node,
                phase_idx: 0,
                remaining: 0.0,
                end_time: 0.0,
                is_ps: false,
                res: ResKind::Disk,
                started: self.now,
                rate: 0.0,
            };
            let mut meter = Meter::default();
            let entered = {
                let st = &self.stages[h];
                enter_phase(
                    self.cluster,
                    &st.phases[ti],
                    r,
                    self.now,
                    &mut self.disk_active,
                    &mut self.nic_active,
                    &mut meter,
                )
            };
            self.apply_meter(h, &meter);
            match entered {
                Some(run) => self.running.push(run),
                None => self.finish_task(h, node, self.now), // zero-work task
            }
        }
    }

    /// Preferred node if it has a free core, else round-robin scan. Call
    /// only when some core is free.
    fn pick_node(&mut self, preferred: Option<NodeId>) -> NodeId {
        let nodes = self.free_cores.len();
        if let Some(p) = preferred {
            let p = p as usize % nodes;
            if self.free_cores[p] > 0 {
                return p as NodeId;
            }
        }
        for k in 0..nodes {
            let cand = (self.rr + k) % nodes;
            if self.free_cores[cand] > 0 {
                self.rr = (cand + 1) % nodes;
                return cand as NodeId;
            }
        }
        unreachable!("pick_node called with no free core")
    }
}

/// Start the task's next non-noop phase (or return `None` when all
/// phases are done). NaN-valued phases are treated as noops — see
/// [`Phase::is_noop`].
fn enter_phase(
    cluster: &ClusterSpec,
    phases: &[Phase],
    mut r: Running,
    now: f64,
    disk_active: &mut [u32],
    nic_active: &mut [u32],
    meter: &mut Meter,
) -> Option<Running> {
    loop {
        let Some(p) = phases.get(r.phase_idx) else {
            return None; // all phases done
        };
        if p.is_noop() {
            r.phase_idx += 1;
            continue;
        }
        match *p {
            Phase::Cpu { secs } => {
                let d = secs / cluster.cpu_speed;
                meter.cpu_secs += d;
                r.is_ps = false;
                r.end_time = now + d;
            }
            Phase::Fixed { secs } => {
                r.is_ps = false;
                r.end_time = now + secs;
            }
            Phase::DiskRead { bytes } | Phase::DiskWrite { bytes } => {
                meter.disk_bytes += bytes;
                r.is_ps = true;
                r.res = ResKind::Disk;
                r.remaining = bytes;
                disk_active[r.node as usize] += 1;
            }
            Phase::NetIn { bytes } => {
                meter.net_bytes += bytes;
                r.is_ps = true;
                r.res = ResKind::Nic;
                r.remaining = bytes;
                nic_active[r.node as usize] += 1;
            }
        }
        return Some(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet() -> ClusterSpec {
        let mut c = ClusterSpec::mini();
        c.task_overhead = 0.0;
        c
    }

    fn opts0() -> SimOpts {
        SimOpts { jitter: 0.0, seed: 1 }
    }

    fn cpu_tasks(n: usize, secs: f64) -> Vec<TaskSpec> {
        (0..n).map(|_| TaskSpec::new(vec![Phase::Cpu { secs }])).collect()
    }

    #[test]
    fn two_stages_interleave_on_shared_cores() {
        // 8 cores; two stages of 8 × 1 s submitted together under FAIR:
        // each job gets 4 cores → both finish at t = 2.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.submit(0, &cpu_tasks(8, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(8, 1.0), &opts0());
        let done = sim.drain();
        assert_eq!(done.len(), 2);
        for d in &done {
            assert!((d.at - 2.0).abs() < 1e-9, "fair finish at {}", d.at);
        }
    }

    #[test]
    fn fifo_prioritizes_the_earlier_job() {
        // Same two stages under FIFO: job 0 takes all 8 cores and
        // finishes at t = 1; job 1 runs after, finishing at t = 2.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &cpu_tasks(8, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(8, 1.0), &opts0());
        let done = sim.drain();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].job, 0);
        assert!((done[0].at - 1.0).abs() < 1e-9, "{}", done[0].at);
        assert_eq!(done[1].job, 1);
        assert!((done[1].at - 2.0).abs() < 1e-9, "{}", done[1].at);
    }

    #[test]
    fn submission_mid_flight_shares_the_disk() {
        // Job 0 writes 100 MB alone on node 0 (disk 100 MB/s). Drain it,
        // then submit two concurrent writers on the same node: they share
        // the disk and take 2 s.
        let mut c = quiet();
        c.disk_bw = 100.0e6;
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &[TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0)], &opts0());
        let first = sim.advance().unwrap();
        assert!((first.at - 1.0).abs() < 1e-6);
        sim.submit(
            1,
            &[
                TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0),
                TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0),
            ],
            &opts0(),
        );
        let second = sim.advance().unwrap();
        assert!((second.at - 3.0).abs() < 1e-6, "{}", second.at);
        assert!(sim.advance().is_none());
    }

    #[test]
    fn completion_waits_for_wave_overhead() {
        let mut c = quiet();
        c.task_overhead = 0.5;
        // 16 tasks on 8 cores → 2 waves → completion at 2×1s + 2×0.5s.
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &cpu_tasks(16, 1.0), &opts0());
        let done = sim.advance().unwrap();
        assert!((done.at - 3.0).abs() < 1e-9, "{}", done.at);
        assert!((done.stats.duration - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stage_completes_immediately() {
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        let h = sim.submit(0, &[], &opts0());
        let done = sim.advance().unwrap();
        assert_eq!(done.handle, h);
        assert!(done.at < 1e-9);
        assert_eq!(done.stats.tasks, 0);
        assert!(sim.advance().is_none());
    }

    #[test]
    fn scheduler_mode_parses() {
        assert_eq!(SchedulerMode::from_config_name("fifo"), Some(SchedulerMode::Fifo));
        assert_eq!(SchedulerMode::from_config_name("FAIR"), Some(SchedulerMode::Fair));
        assert_eq!(SchedulerMode::from_config_name("fair "), Some(SchedulerMode::Fair));
        assert_eq!(SchedulerMode::from_config_name("lottery"), None);
        assert_eq!(SchedulerMode::Fifo.config_name(), "FIFO");
        assert_eq!(scheduler_for(SchedulerMode::Fair).name(), "FAIR");
    }

    #[test]
    fn event_core_is_deterministic_across_runs() {
        let c = ClusterSpec::mini();
        let mk = || {
            let mut sim = EventSim::new(&c, Box::new(FairScheduler));
            for j in 0..3usize {
                let tasks: Vec<TaskSpec> = (0..10)
                    .map(|i| {
                        TaskSpec::new(vec![
                            Phase::Cpu { secs: 0.1 + (i % 3) as f64 * 0.05 },
                            Phase::DiskWrite { bytes: 2e6 },
                            Phase::NetIn { bytes: 1e6 },
                        ])
                    })
                    .collect();
                sim.submit(j, &tasks, &SimOpts { jitter: 0.08, seed: 7 + j as u64 });
            }
            sim.drain().iter().map(|d| (d.handle, d.at)).collect::<Vec<_>>()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "event core must reproduce bit-identically");
    }

    #[test]
    fn nan_phases_are_noops() {
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(
            0,
            &[TaskSpec::new(vec![
                Phase::Cpu { secs: f64::NAN },
                Phase::DiskRead { bytes: f64::NAN },
                Phase::Cpu { secs: 1.0 },
            ])],
            &opts0(),
        );
        let done = sim.advance().unwrap();
        assert!(done.at.is_finite(), "NaN phases must not poison the clock");
        assert!((done.at - 1.0).abs() < 1e-9, "{}", done.at);
    }
}
