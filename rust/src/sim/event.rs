//! The persistent event core: a single global event queue scheduling
//! **individual tasks** from many stages of many jobs at once over the
//! modeled cluster.
//!
//! [`EventSim`] owns the cluster's contended state — per-node core slots
//! and the processor-shared disk/NIC flow sets — for the whole lifetime
//! of a simulation. Stages are [`submit`](EventSim::submit)ted as they
//! become runnable (the engine submits a stage the moment its DAG
//! parents complete) and the core interleaves their tasks freely: a
//! reduce stage of job A shares disks and NICs with a map stage of job B
//! at fair fluid-flow rates, exactly as concurrent Spark jobs contend on
//! one cluster.
//!
//! # The hot path: indexed event discovery
//!
//! Time only moves at events, and between events every quantity the core
//! tracks is either constant or linear in time. The core exploits that
//! end to end — no per-event rescans of the running set:
//!
//! * every running task copy carries an **absolute predicted finish
//!   time** (its *deadline*), kept in a hand-rolled indexed min-heap
//!   ([`TimeHeap`]) with O(log n) decrease/increase-key;
//! * processor-shared disk/NIC flows progress at their cached fair-share
//!   rate. Rates only change when a flow enters or leaves a resource —
//!   which only happens at events — so the core marks exactly the
//!   touched resources **dirty** and re-rolls *only their* flows
//!   (`remaining`, `rate`, deadline) before the next event is chosen.
//!   This dirty-set propagation is exact, not an approximation;
//! * stage completions sit in their own min-heap; locality-hold expiries
//!   live in a monotone deque (one deadline per stage, all sharing the
//!   same `locality_wait`); speculation-threshold crossings are derived
//!   from per-stage launch-ordered queues of running originals (earliest
//!   launch ⇒ earliest threshold crossing) plus a per-stage cached
//!   threshold invalidated when a task of the stage finishes.
//!
//! A reference **scan core** ([`Discovery::Scan`]) shares every byte of
//! this state and processing code but discovers the next event by
//! scanning all live copies — and *asserts*, every event, that the
//! cached fair-share rates match a fresh recomputation (so a missed
//! dirty mark fails loudly). Scan and indexed cores produce bit-identical
//! [`StageCompletion`] streams; the golden equivalence suite pins that.
//! [`SimStats`] counts the work each core did, so speedups are
//! explainable: `live_copy_event_sum` is what per-event rescans would
//! have touched, `flow_rolls` is what the dirty rule actually touched.
//!
//! # Task-granular scheduling features
//!
//! Tasks are first-class schedulable units, each with its own launch and
//! finish events:
//!
//! * **Delay scheduling** (`spark.locality.wait`, [`SimPolicy`]): a task
//!   with preferred nodes *holds* for up to `locality_wait` simulated
//!   seconds (from its stage's submission) for a free core on one of
//!   them, then degrades to ANY placement. A stage whose pending tasks
//!   are all holding is skipped by admission entirely — later stages and
//!   other jobs take the cores, as in Zaharia's delay scheduler.
//! * **Speculative execution** (`spark.speculation`, [`SpecPolicy`]):
//!   once a stage has at least `quantile` of its tasks done, any running
//!   task whose elapsed time exceeds `multiplier` × the median successful
//!   duration is cloned onto a *different* node. The first finisher wins;
//!   the loser is cancelled — its core freed, its processor-shared flow
//!   withdrawn mid-stream, and the stage's resource meters refunded for
//!   the work it never completed.
//!
//! **Which** pending task gets a freed core is delegated to a pluggable
//! [`Scheduler`] — the analogue of Spark's `spark.scheduler.mode`:
//!
//! * [`FifoScheduler`] — earlier-submitted jobs win; within a job,
//!   earlier-submitted stages win (Spark's default FIFO pool ordering by
//!   job submission time).
//! * [`FairScheduler`] — Spark's fair-scheduling algorithm over per-job
//!   [`PoolSpec`]s: pools below their `minShare` first (by
//!   running/minShare), then by running/`weight`. With default pools it
//!   reduces to fewest-running-tasks-first.
//!
//! Per-task state lives in **flat arenas**: one phase arena + offset
//! table per stage (jittered originals and re-jittered speculative
//! clones side by side), one preferred-node arena, and a slot arena of
//! running copies with a LIFO free list — stage submission performs a
//! constant number of allocations however many tasks it carries, and the
//! engine's uniform stages submit through [`StageSpec`] without
//! materializing per-task [`TaskSpec`]s at all.
//!
//! Everything is deterministic in `(submission order, SimOpts seed)`:
//! repeated runs produce bit-identical clocks regardless of discovery
//! mode. A stage *completes* `waves × task_overhead` after its last task
//! finishes; its [`StageCompletion`] — which also carries the node every
//! task actually ran on, so the engine can derive cache-locality
//! preferences for child stages — is surfaced to the driver from
//! [`advance`](EventSim::advance).

use super::fault::{FaultEvent, FaultPlan, RecoveryPolicy, TimelineEvent};
use super::{Phase, SimOpts, StageStats, TaskSpec};
use crate::cluster::{ClusterSpec, NodeId};
use crate::obs::{SpanId, TraceSink};
use crate::util::stats::Summary;
use crate::util::Prng;
use std::cmp::Ordering;
use std::collections::VecDeque;
use std::fmt;
use std::sync::Arc;

/// Identifies one submitting job within an [`EventSim`] (the engine uses
/// the job's index in the submission batch).
pub type JobId = usize;

/// Handle for a submitted stage, unique within one [`EventSim`].
pub type StageHandle = usize;

/// `spark.scheduler.mode` — how concurrently runnable tasks from
/// different jobs are ordered onto free cores.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SchedulerMode {
    /// Jobs get cores in submission order (Spark's default).
    #[default]
    Fifo,
    /// Running-task counts are balanced across jobs, honoring per-pool
    /// `weight` / `minShare`.
    Fair,
}

impl SchedulerMode {
    pub const ALL: [SchedulerMode; 2] = [SchedulerMode::Fifo, SchedulerMode::Fair];

    pub fn config_name(self) -> &'static str {
        match self {
            SchedulerMode::Fifo => "FIFO",
            SchedulerMode::Fair => "FAIR",
        }
    }

    pub fn from_config_name(s: &str) -> Option<SchedulerMode> {
        match s.trim().to_ascii_uppercase().as_str() {
            "FIFO" => Some(SchedulerMode::Fifo),
            "FAIR" => Some(SchedulerMode::Fair),
            _ => None,
        }
    }
}

impl fmt::Display for SchedulerMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.config_name())
    }
}

/// FAIR-pool configuration for one job — Spark's per-pool `weight` /
/// `minShare` from the fair-scheduler allocation file.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PoolSpec {
    /// Relative core share once no pool is below its minimum.
    pub weight: f64,
    /// Cores this pool is entitled to before weighted sharing applies.
    pub min_share: u32,
}

impl Default for PoolSpec {
    fn default() -> PoolSpec {
        PoolSpec { weight: 1.0, min_share: 0 }
    }
}

/// `spark.speculation.*`: once a stage has at least `quantile` of its
/// tasks finished, tasks running longer than `multiplier` × the median
/// successful task duration get a backup copy on another node; the first
/// finisher wins and the loser's resource flows are cancelled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpecPolicy {
    /// Fraction of the stage's tasks that must be complete before
    /// speculation kicks in (Spark default 0.75).
    pub quantile: f64,
    /// How many times slower than the median a task must be to get a
    /// backup (Spark default 1.5).
    pub multiplier: f64,
}

/// Core-wide scheduling policy beyond the [`Scheduler`] trait: delay
/// scheduling and speculative execution. `Default` disables both.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SimPolicy {
    /// `spark.locality.wait` in simulated seconds: how long a task with
    /// preferred nodes holds for a local core before degrading to ANY.
    /// The hold window is measured from its stage's submission — a
    /// deterministic simplification of Spark's per-level reset timer.
    pub locality_wait: f64,
    /// `spark.speculation` (`None` = off).
    pub speculation: Option<SpecPolicy>,
}

/// How the core finds the next event.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Discovery {
    /// Reference mode: scan every live task copy at every event, and
    /// assert the indexed bookkeeping invariants (cached fair-share
    /// rates fresh, flow lists consistent). Used by the golden
    /// equivalence tests; O(running) per event.
    Scan,
    /// Production mode: indexed min-heaps + dirty-resource propagation;
    /// O(log n) per event plus O(touched flows).
    #[default]
    Indexed,
}

/// Event-core work counters: what the simulation did and — the point of
/// the indexed queue — what it *avoided* doing. Snapshot via
/// [`EventSim::stats`]; the engine surfaces the final snapshot on
/// `JobResult`/`MultiJobResult` and the report layer renders it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Clock-advancing events processed.
    pub events: u64,
    /// Stage completions emitted.
    pub completions: u64,
    /// Task copies launched (originals + speculative clones).
    pub task_launches: u64,
    /// Non-noop phase entries.
    pub phase_transitions: u64,
    /// Task-event heap insertions (zero in [`Discovery::Scan`]).
    pub heap_pushes: u64,
    /// Task-event heap pops (zero in [`Discovery::Scan`]).
    pub heap_pops: u64,
    /// Task-event heap re-keys — decrease/increase-key operations
    /// (zero in [`Discovery::Scan`]).
    pub heap_updates: u64,
    /// Processor-shared flow rolls: deadline/rate recomputations actually
    /// performed under the dirty-resource rule.
    pub flow_rolls: u64,
    /// Σ over events of live running copies — the per-event scan work a
    /// rescanning core would have performed.
    pub live_copy_event_sum: u64,
    /// Admission-bucket probes: pending-task peeks (and lazy-deletion
    /// pops) the bucketed admission path actually performed. A linear
    /// admission scan would have touched every pending task of every
    /// offered stage instead.
    pub admit_probes: u64,
    /// Events inherited from a checkpoint instead of being re-processed
    /// — incremental re-pricing's saved work. Zero on full runs; on a
    /// resumed run, `events` still counts the *whole* timeline
    /// (inherited + processed), so `events - replayed_events` is what
    /// this trial actually cost.
    pub replayed_events: u64,
    /// Runs that resumed from a [`SimCheckpoint`] (0 or 1 per core;
    /// aggregates across trials via [`absorb`](SimStats::absorb)).
    pub forked_trials: u64,
    /// Winning task finishes (one per task; losing speculative copies
    /// are not counted). A *logical* timeline counter — identical
    /// between a resumed run and a full run — that also paces mid-stage
    /// snapshotting ([`SnapshotSink`]).
    pub task_finishes: u64,
    /// Events whose clock time came from a speculation-threshold
    /// crossing (strictly earlier than every queued task/completion/hold
    /// deadline). Zero means speculation never perturbed the timeline —
    /// the fact the incremental re-pricer's policy-fork validity checks
    /// rely on.
    pub spec_events: u64,
    /// Task-copy failures injected by an armed [`FaultPlan`] (transient
    /// crashes at output commit). Zero whenever faults are disarmed —
    /// the re-pricer's failure-policy fork certificate relies on it.
    pub task_failures: u64,
    /// Failed or executor-lost tasks re-queued for another attempt.
    pub task_retries: u64,
    /// Stages aborted past `spark.task.maxFailures` (the owning job
    /// crashes → INFINITY makespan).
    pub stage_aborts: u64,
    /// Scheduled executor/node losses applied from the fault timeline.
    pub executor_losses: u64,
    /// Node restarts applied after a down window.
    pub executor_restarts: u64,
}

impl SimStats {
    /// Scan work the dirty-resource rule avoided: live copies per event
    /// a rescanning discovery would have touched, minus the flow rolls
    /// actually performed. (In [`Discovery::Scan`] the discovery itself
    /// still touches every live copy; this counter then reports what the
    /// indexed core *would* have saved on the same run.)
    pub fn scan_work_saved(&self) -> u64 {
        self.live_copy_event_sum.saturating_sub(self.flow_rolls)
    }

    /// Total task-event heap operations.
    pub fn heap_ops(&self) -> u64 {
        self.heap_pushes + self.heap_pops + self.heap_updates
    }

    /// Events this run actually processed: the full timeline minus the
    /// prefix inherited from a checkpoint.
    pub fn processed_events(&self) -> u64 {
        self.events.saturating_sub(self.replayed_events)
    }

    /// The counters that describe the *simulated timeline* rather than
    /// how it was obtained: incremental bookkeeping (`replayed_events`,
    /// `forked_trials`) zeroed. A resumed run and a full run of the same
    /// trial are bit-identical under this projection — the equality the
    /// golden oracles pin.
    pub fn logical(&self) -> SimStats {
        SimStats { replayed_events: 0, forked_trials: 0, ..*self }
    }

    /// Fold another snapshot into this one (aggregating across runs —
    /// the CLI's `perf-smoke` totals, for example). Destructures
    /// exhaustively so adding a counter without summing it here is a
    /// compile error, not a silently-zero report row.
    pub fn absorb(&mut self, other: &SimStats) {
        let SimStats {
            events,
            completions,
            task_launches,
            phase_transitions,
            heap_pushes,
            heap_pops,
            heap_updates,
            flow_rolls,
            live_copy_event_sum,
            admit_probes,
            replayed_events,
            forked_trials,
            task_finishes,
            spec_events,
            task_failures,
            task_retries,
            stage_aborts,
            executor_losses,
            executor_restarts,
        } = *other;
        self.events += events;
        self.completions += completions;
        self.task_launches += task_launches;
        self.phase_transitions += phase_transitions;
        self.heap_pushes += heap_pushes;
        self.heap_pops += heap_pops;
        self.heap_updates += heap_updates;
        self.flow_rolls += flow_rolls;
        self.live_copy_event_sum += live_copy_event_sum;
        self.admit_probes += admit_probes;
        self.replayed_events += replayed_events;
        self.forked_trials += forked_trials;
        self.task_finishes += task_finishes;
        self.spec_events += spec_events;
        self.task_failures += task_failures;
        self.task_retries += task_retries;
        self.stage_aborts += stage_aborts;
        self.executor_losses += executor_losses;
        self.executor_restarts += executor_restarts;
    }
}

/// What a [`Scheduler`] sees of one runnable stage when picking the next
/// task to admit. Candidates are stages with at least one *admissible*
/// pending task under the current free cores and locality state — a
/// stage whose pending tasks are all holding for busy local nodes is not
/// offered (delay scheduling).
#[derive(Clone, Copy, Debug)]
pub struct StageView {
    /// Handle of the stage (return this from [`Scheduler::pick`]).
    pub handle: StageHandle,
    /// Submitting job.
    pub job: JobId,
    /// Global submission sequence number of the stage.
    pub seq: usize,
    /// Tasks of this stage still waiting for a core.
    pub pending: usize,
    /// Tasks of this stage's *job* currently holding cores.
    pub job_running: usize,
    /// FAIR-pool weight of the job (1.0 unless configured).
    pub weight: f64,
    /// FAIR-pool minimum core share of the job (0 unless configured).
    pub min_share: u32,
}

/// Task-admission policy: given the stages that currently have admissible
/// pending tasks, choose the stage whose next task gets the free core.
///
/// Implementations must be deterministic functions of the view (the
/// event core's reproducibility guarantee depends on it).
pub trait Scheduler {
    /// Policy name for reports.
    fn name(&self) -> &'static str;

    /// Pick a stage from `candidates` (all have an admissible pending
    /// task; the slice is ordered by handle). Returning `None` leaves the
    /// cores idle until the next submission.
    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle>;
}

/// FIFO: lowest job id first (jobs are numbered in submission order),
/// then lowest stage submission sequence.
#[derive(Clone, Copy, Debug, Default)]
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle> {
        candidates.iter().min_by_key(|s| (s.job, s.seq)).map(|s| s.handle)
    }
}

/// FAIR: Spark's `FairSchedulingAlgorithm` over per-job pools — see
/// [`fair_order`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FairScheduler;

impl Scheduler for FairScheduler {
    fn name(&self) -> &'static str {
        "FAIR"
    }

    fn pick(&mut self, candidates: &[StageView]) -> Option<StageHandle> {
        candidates.iter().min_by(|a, b| fair_order(a, b)).map(|s| s.handle)
    }
}

/// Spark's fair comparator: pools below their `minShare` come first
/// (ordered by running/minShare); otherwise pools order by
/// running/`weight`. Ties break on (job, seq), making the order total
/// and deterministic. With default pools (weight 1, minShare 0) this
/// reduces to fewest-running-tasks-first — the historical FAIR behavior,
/// bit for bit.
fn fair_order(a: &StageView, b: &StageView) -> Ordering {
    let a_needy = (a.job_running as u32) < a.min_share;
    let b_needy = (b.job_running as u32) < b.min_share;
    match (a_needy, b_needy) {
        (true, false) => return Ordering::Less,
        (false, true) => return Ordering::Greater,
        _ => {}
    }
    let (ra, rb) = if a_needy {
        (
            a.job_running as f64 / a.min_share.max(1) as f64,
            b.job_running as f64 / b.min_share.max(1) as f64,
        )
    } else {
        (
            a.job_running as f64 / a.weight.max(f64::MIN_POSITIVE),
            b.job_running as f64 / b.weight.max(f64::MIN_POSITIVE),
        )
    };
    ra.partial_cmp(&rb)
        .unwrap_or(Ordering::Equal)
        .then_with(|| (a.job, a.seq).cmp(&(b.job, b.seq)))
}

/// Instantiate the scheduler for a mode.
pub fn scheduler_for(mode: SchedulerMode) -> Box<dyn Scheduler> {
    match mode {
        SchedulerMode::Fifo => Box::new(FifoScheduler),
        SchedulerMode::Fair => Box::new(FairScheduler),
    }
}

/// Emitted by [`EventSim::advance`] when a submitted stage has fully
/// finished (all tasks done + the stage's wave overhead elapsed).
#[derive(Clone, Debug)]
pub struct StageCompletion {
    pub handle: StageHandle,
    pub job: JobId,
    /// Event-clock time of the completion.
    pub at: f64,
    pub stats: StageStats,
    /// The node each task's *winning* copy ran on, indexed by task — the
    /// engine derives cache-read locality preferences for child stages
    /// from this (cached blocks live where their writer actually ran).
    pub task_nodes: Vec<NodeId>,
    /// The stage aborted (a task exhausted `spark.task.maxFailures`):
    /// `at` is the abort instant, the stats cover only finished work,
    /// and the owning job must be treated as crashed.
    pub aborted: bool,
}

/// A uniform stage for the fast submission path: every task shares one
/// phase template and a fixed-width preferred-node list (one entry for
/// plain block locality, several for replicated blocks). The engine's
/// priced stages are exactly this shape; submitting through
/// [`EventSim::submit_shaped`] skips the per-task [`TaskSpec`]
/// materialization (and its per-task `Vec` allocations) entirely —
/// including for replicated-input stages, which previously had to fall
/// back to per-task specs. Results are bit-identical to the equivalent
/// [`EventSim::submit`].
#[derive(Clone, Copy, Debug)]
pub struct StageSpec<'a> {
    /// Phase template shared by every task (jitter is applied per task).
    pub template: &'a [Phase],
    /// Preferred nodes, `pref_width` per task, task-major: task `t` owns
    /// `preferred[t*pref_width..(t+1)*pref_width]`. Either empty (no
    /// task has a preference) or exactly `tasks × pref_width` long.
    pub preferred: &'a [NodeId],
    /// Preference-list entries per task (ignored when `preferred` is
    /// empty; a replica count for replicated-block inputs).
    pub pref_width: usize,
    /// Task count.
    pub tasks: usize,
}

// ---------------------------------------------------------------------------
// Indexed min-heap
// ---------------------------------------------------------------------------

/// Slot id marker for "not in the heap".
const ABSENT: u32 = u32::MAX;

/// Hand-rolled indexed binary min-heap over `(time, id)` keys: `set`
/// inserts or re-keys (decrease- *and* increase-key) in O(log n), and
/// `remove` deletes by id in O(log n) via a position table. Ties break
/// on the id, making peek/pop order a total, deterministic function of
/// the contents. Keys must not be NaN (the phase translator's
/// `Phase::is_noop` NaN guard upholds this).
#[derive(Clone)]
struct TimeHeap {
    /// `(key, id)` pairs in heap order (minimum at index 0).
    items: Vec<(f64, u32)>,
    /// id → index in `items` (`ABSENT` when the id is not queued).
    pos: Vec<u32>,
}

impl TimeHeap {
    fn new() -> TimeHeap {
        TimeHeap { items: Vec::new(), pos: Vec::new() }
    }

    fn len(&self) -> usize {
        self.items.len()
    }

    fn contains(&self, id: u32) -> bool {
        (id as usize) < self.pos.len() && self.pos[id as usize] != ABSENT
    }

    fn peek(&self) -> Option<(f64, u32)> {
        self.items.first().copied()
    }

    /// Insert `id` with `key`, or re-key it if already queued. Returns
    /// `true` when the id was inserted fresh.
    fn set(&mut self, id: u32, key: f64) -> bool {
        debug_assert!(!key.is_nan(), "NaN event time would poison the queue");
        if id as usize >= self.pos.len() {
            self.pos.resize(id as usize + 1, ABSENT);
        }
        let p = self.pos[id as usize];
        if p == ABSENT {
            self.items.push((key, id));
            let i = self.items.len() - 1;
            self.pos[id as usize] = i as u32;
            self.sift_up(i);
            true
        } else {
            self.items[p as usize].0 = key;
            self.fix(p as usize);
            false
        }
    }

    fn pop(&mut self) -> Option<(f64, u32)> {
        let top = *self.items.first()?;
        self.remove_at(0);
        Some(top)
    }

    /// Batch-pop every entry with key ≤ `cutoff` (the minimum-timestamp
    /// tie group plus anything inside the same epsilon window), pushing
    /// the ids onto `out` and returning how many were popped.
    ///
    /// The due entries form a root-connected subtree (heap property:
    /// a parent past the cutoff has no due descendants), so a pruned
    /// walk touches only them plus their fringe; holes are then filled
    /// from the tail and one Floyd-style descending `sift_down` pass
    /// over the vacated positions restores the heap — replacing the
    /// per-event pop/sift cycle per tie. Pop *order* within the batch is
    /// heap-layout order; callers needing the canonical tie order
    /// (ascending id, as `pop` yields) sort the batch.
    fn pop_due_into(&mut self, cutoff: f64, out: &mut Vec<u32>) -> usize {
        let Some(&(top, _)) = self.items.first() else { return 0 };
        if top > cutoff {
            return 0;
        }
        // Pruned DFS over the due subtree, recording vacated positions.
        let mut holes: Vec<usize> = vec![0];
        let mut i = 0;
        while i < holes.len() {
            let p = holes[i];
            i += 1;
            let (_, id) = self.items[p];
            self.pos[id as usize] = ABSENT;
            out.push(id);
            for child in [2 * p + 1, 2 * p + 2] {
                if child < self.items.len() && self.items[child].0 <= cutoff {
                    holes.push(child);
                }
            }
        }
        let popped = holes.len();
        // Fill holes from the tail, largest position first: every hole
        // above the current one is already gone, so the tail is always a
        // survivor (or the hole itself).
        holes.sort_unstable_by(|a, b| b.cmp(a));
        for &p in &holes {
            let last = self.items.len() - 1;
            self.items.swap(p, last);
            self.items.pop();
            if p < self.items.len() {
                self.pos[self.items[p].1 as usize] = p as u32;
            }
        }
        // Descending-position sift_down = partial Floyd heapify over the
        // refilled subtree (children of each fixed position are valid
        // heaps by the time it is processed, deepest holes first). The
        // subtree is rooted at position 0, so nothing ever sifts up.
        for &p in &holes {
            if p < self.items.len() {
                self.sift_down(p);
            }
        }
        popped
    }

    /// Remove `id` if queued (no-op otherwise).
    fn remove(&mut self, id: u32) {
        if self.contains(id) {
            let p = self.pos[id as usize] as usize;
            self.remove_at(p);
        }
    }

    fn remove_at(&mut self, p: usize) {
        let (_, id) = self.items[p];
        self.pos[id as usize] = ABSENT;
        let last = self.items.len() - 1;
        self.items.swap(p, last);
        self.items.pop();
        if p < self.items.len() {
            // The displaced ex-last element may need to move either way.
            self.pos[self.items[p].1 as usize] = p as u32;
            self.fix(p);
        }
    }

    /// Restore the heap property around `p` after its key changed.
    fn fix(&mut self, p: usize) {
        if p > 0 && self.less(p, (p - 1) / 2) {
            self.sift_up(p);
        } else {
            self.sift_down(p);
        }
    }

    fn less(&self, a: usize, b: usize) -> bool {
        let (ka, ia) = self.items[a];
        let (kb, ib) = self.items[b];
        ka < kb || (ka == kb && ia < ib)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.less(i, parent) {
                self.swap_items(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let l = 2 * i + 1;
            let r = l + 1;
            let mut m = i;
            if l < self.items.len() && self.less(l, m) {
                m = l;
            }
            if r < self.items.len() && self.less(r, m) {
                m = r;
            }
            if m == i {
                break;
            }
            self.swap_items(i, m);
            i = m;
        }
    }

    fn swap_items(&mut self, a: usize, b: usize) {
        self.items.swap(a, b);
        self.pos[self.items[a].1 as usize] = a as u32;
        self.pos[self.items[b].1 as usize] = b as u32;
    }

    /// Heap footprint of the queue's buffers.
    fn bytes(&self) -> usize {
        self.items.len() * std::mem::size_of::<(f64, u32)>()
            + self.pos.len() * std::mem::size_of::<u32>()
    }
}

// ---------------------------------------------------------------------------
// Runtime state
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ResKind {
    Disk,
    Nic,
}

/// One running task copy in the slot arena. A copy keeps its slot for
/// its whole lifetime (all phases); the slot is recycled through a LIFO
/// free list when the copy finishes, is cancelled, or goes moot.
#[derive(Clone)]
struct Running {
    stage: u32,
    task_idx: u32,
    node: NodeId,
    phase_idx: u32,
    /// Position in its resource's flow list (PS phases only).
    res_pos: u32,
    /// Launch time of this copy.
    started: f64,
    /// Absolute predicted finish time of the current phase — the heap
    /// key. Exact for fixed-rate phases; for PS phases it is valid
    /// whenever the resource is clean (rates change only at events, and
    /// dirty resources are re-rolled before discovery).
    deadline: f64,
    /// PS phases: bytes left as of `updated_at`.
    remaining: f64,
    /// PS phases: time of the last roll (rate change on this resource).
    updated_at: f64,
    /// PS phases: cached fair-share rate since `updated_at`.
    rate: f64,
    is_ps: bool,
    res: ResKind,
    /// Current phase is a metered CPU phase (for cancellation refunds).
    is_cpu: bool,
    /// This copy is a speculative backup.
    is_clone: bool,
    /// An armed [`FaultPlan`] doomed this copy at launch: it consumes
    /// its full duration, then fails at output commit instead of
    /// finishing (a pure per-launch draw — no live RNG state).
    doomed: bool,
    alive: bool,
    /// Pulled out of the event queue for the event being processed right
    /// now. A sibling in this state is about to be handled as a moot
    /// finisher — `cancel_sibling` must not touch it (first-finisher
    /// ties resolve through the moot path, with no refunds).
    collected: bool,
    /// Slot of this copy's speculation sibling (`SLOT_NONE` until a
    /// backup is launched): the racing pair link each other so the
    /// winner cancels the loser in O(1) instead of scanning the arena.
    sibling: u32,
}

/// "No slot" marker for [`Running::sibling`].
const SLOT_NONE: u32 = u32::MAX;

/// The immutable-after-submission arenas of one stage: phase templates
/// (with all jitter/straggler/clone draws already applied) and the
/// preferred-node table. Split out of [`StageRt`] behind an `Arc` so
/// checkpoints delta-encode against the live core — cloning a
/// [`SimCheckpoint`]'s stages shares these arenas structurally (a
/// pointer bump, not a memcpy), which is where the bulk of a stage's
/// footprint lives. [`SimCheckpoint::owned_bytes`] counts them once per
/// distinct arena, not once per snapshot.
#[derive(Clone)]
struct StageArena {
    /// Jittered (and possibly straggler-scaled) phases, all tasks
    /// back-to-back; task `t` owns `phases[phase_off[t]..phase_off[t+1]]`.
    phases: Vec<Phase>,
    /// Re-jittered phases for speculative copies (no straggler factor —
    /// the backup lands on a healthy node). Shares `phase_off`; empty
    /// when speculation is off.
    clone_phases: Vec<Phase>,
    phase_off: Vec<u32>,
    /// Preferred nodes, all tasks back-to-back (empty slice = ANY).
    preferred: Vec<NodeId>,
    pref_off: Vec<u32>,
}

impl StageArena {
    /// Heap footprint of the arena buffers.
    fn bytes(&self) -> usize {
        use std::mem::size_of;
        self.phases.len() * size_of::<Phase>()
            + self.clone_phases.len() * size_of::<Phase>()
            + self.phase_off.len() * size_of::<u32>()
            + self.preferred.len() * size_of::<NodeId>()
            + self.pref_off.len() * size_of::<u32>()
    }
}

/// Per-stage runtime state: flat arenas + offset tables, so submission
/// allocates a constant number of vectors however many tasks the stage
/// carries.
#[derive(Clone)]
struct StageRt {
    job: JobId,
    seq: usize,
    /// Task count.
    tasks: usize,
    /// The `SimOpts` seed the stage was submitted under — the stage half
    /// of every fault draw's key ([`FaultPlan::dooms`]).
    seed: u64,
    /// Per-task failed-attempt counts (fault injection only; all zero on
    /// fault-free runs). Doubles as the attempt number of the next
    /// launch, so retry draws are distinct by construction.
    failures: Vec<u32>,
    /// A task exhausted `spark.task.maxFailures`: the stage completed
    /// via the abort path and must never admit again.
    aborted: bool,
    /// Handle is currently in the core's `pending_list` (requeue-time
    /// membership test — the list is otherwise append-only per stage).
    in_pending_list: bool,
    /// Immutable phase/preference arenas, shared with every checkpoint
    /// of this core (see [`StageArena`]).
    arena: Arc<StageArena>,
    pending: VecDeque<u32>,
    /// How many pending tasks still carry a locality preference (drives
    /// hold-expiry bookkeeping).
    pending_pref: usize,
    /// Admission buckets: pending tasks by preferred node (ascending
    /// task index; one entry per preference, so multi-replica tasks sit
    /// in several buckets). Entries go stale when their task launches
    /// and are pruned lazily from the front — a free core probes its own
    /// bucket's front instead of scanning the whole pending queue.
    node_buckets: Vec<VecDeque<u32>>,
    /// Pending tasks with no locality preference, ascending.
    nopref_queue: VecDeque<u32>,
    /// Task is still in `pending` (the buckets' lazy-deletion test).
    in_pending: Vec<bool>,
    /// Task finished (winning copy completed).
    done: Vec<bool>,
    /// Task has a speculative backup copy (launched at most once).
    cloned: Vec<bool>,
    /// Tasks not yet finished.
    unfinished: usize,
    submitted_at: f64,
    /// Clock time of the admission that emptied `pending` (`INFINITY`
    /// while tasks are still pending; `submitted_at` for empty stages).
    /// Bounds every admission-time locality decision this stage ever
    /// made — the fact behind the re-pricer's locality-wait fork
    /// validity check ([`SimCheckpoint::locality_fork_ok`]).
    drained_at: f64,
    task_durations: Vec<f64>,
    /// `task_durations` kept sorted incrementally — the speculation
    /// median without per-event re-sorts. Maintained only under an
    /// active speculation policy.
    durations_sorted: Vec<f64>,
    /// Cached speculation threshold (`multiplier × median`), invalidated
    /// by `spec_dirty` whenever a task of this stage finishes.
    spec_th: Option<f64>,
    spec_dirty: bool,
    /// Stage is registered in the core's speculation list.
    in_spec_list: bool,
    /// Running *original* copies in launch order (`started`
    /// non-decreasing): the front is always the earliest-launched — and
    /// therefore first-to-cross-the-threshold — candidate. Entries go
    /// stale when their task finishes/clones or the slot is recycled;
    /// they are validated lazily and pruned from the front.
    orig_queue: VecDeque<(u32, u32)>,
    /// Node the winning copy of each task ran on.
    task_nodes: Vec<NodeId>,
    /// Tasks launched on one of their preferred nodes.
    locality_hits: usize,
    /// Speculative copies launched.
    speculated: usize,
    cpu_secs: f64,
    disk_bytes: f64,
    net_bytes: f64,
    /// `waves × task_overhead`, charged between the last task finish and
    /// the stage's completion event.
    completion_overhead: f64,
}

impl StageRt {
    fn task_phases(&self, t: usize) -> &[Phase] {
        let a = &self.arena;
        &a.phases[a.phase_off[t] as usize..a.phase_off[t + 1] as usize]
    }

    fn clone_task_phases(&self, t: usize) -> &[Phase] {
        let a = &self.arena;
        &a.clone_phases[a.phase_off[t] as usize..a.phase_off[t + 1] as usize]
    }

    fn task_prefs(&self, t: usize) -> &[NodeId] {
        let a = &self.arena;
        &a.preferred[a.pref_off[t] as usize..a.pref_off[t + 1] as usize]
    }

    /// The task carries at least one locality preference.
    fn task_has_pref(&self, t: usize) -> bool {
        self.arena.pref_off[t + 1] > self.arena.pref_off[t]
    }
}

/// The persistent, multi-stage, multi-job discrete-event simulator core
/// (see module docs).
pub struct EventSim<'a> {
    cluster: &'a ClusterSpec,
    scheduler: Box<dyn Scheduler>,
    policy: SimPolicy,
    discovery: Discovery,
    now: f64,
    free_cores: Vec<i64>,
    /// Σ `free_cores` — the O(1) "any core free?" probe.
    free_core_total: i64,
    /// Live flow slots per resource; disks first, then NICs
    /// (`res = node` / `res = nodes + node`). The list length *is* the
    /// active-flow count that sets the fair-share rate.
    flows: Vec<Vec<u32>>,
    res_dirty: Vec<bool>,
    /// Dirty resource indices awaiting a roll.
    dirty: Vec<u32>,
    /// Slot arena of running copies + LIFO free list.
    slots: Vec<Running>,
    free_slots: Vec<u32>,
    live: usize,
    /// Task phase-end events ([`Discovery::Indexed`] only).
    task_heap: TimeHeap,
    /// Stage completion events, keyed `(due, handle)`.
    completions: TimeHeap,
    /// Locality-hold expiries `(deadline, handle)` — deadlines are
    /// monotone in submission order (one shared `locality_wait`), so a
    /// deque with lazy front-pruning replaces a per-event stage scan.
    holds: VecDeque<(f64, u32)>,
    /// Stages with running originals under an active speculation policy
    /// (lazily compacted).
    spec_list: Vec<u32>,
    stages: Vec<StageRt>,
    /// Stages with pending tasks, ascending by handle (lazily compacted).
    pending_list: Vec<u32>,
    /// Running task-copy count per job (indexed by `JobId`).
    jobs_running: Vec<usize>,
    /// FAIR pool per job (default weight 1 / minShare 0).
    pools: Vec<PoolSpec>,
    /// Round-robin cursor for locality-free placement.
    rr: usize,
    /// Admission gate: only rescan pending work when cores were freed,
    /// stages were submitted, or a locality deadline passed since the
    /// last pass.
    admit_dirty: bool,
    stats: SimStats,
    /// Reused scratch for same-event finisher collection.
    finished_scratch: Vec<u32>,
    /// Observability recorder (null by default — a one-branch no-op).
    /// Deliberately *not* part of [`SimCheckpoint`]: observation is
    /// never value state, so resumed cores start with a fresh (null)
    /// sink and the engine re-attaches its own.
    trace: TraceSink,
    /// Trace span bound to each stage handle ([`SpanId::NONE`] when the
    /// stage was submitted before tracing attached, e.g. a resumed
    /// prefix).
    stage_spans: Vec<SpanId>,
    /// Armed fault injector + recovery policy (`None` = today's
    /// fault-free core, bit for bit).
    faults: Option<FaultRt>,
    /// Fault/recovery notifications queued for the engine — drained via
    /// [`take_fault_events`](Self::take_fault_events) (the engine's
    /// FetchFailed resubmission path keys off `ExecutorLost`).
    fault_events: Vec<FaultEvent>,
}

/// Live injector state: the armed plan, the recovery policy in force,
/// the loss/restart timeline cursor, and per-node health. Pure value
/// state (every crash draw is a pure function of launch-time facts), so
/// checkpoints clone it wholesale.
#[derive(Clone)]
struct FaultRt {
    plan: Arc<FaultPlan>,
    recovery: RecoveryPolicy,
    /// Sorted loss/restart instants ([`FaultPlan::timeline`]).
    timeline: Vec<TimelineEvent>,
    /// Next unapplied timeline entry.
    cursor: usize,
    /// Node is currently down (lost, not yet restarted).
    down: Vec<bool>,
    /// Node was excluded from placement (`spark.excludeOnFailure`);
    /// exclusion is permanent for the run.
    excluded: Vec<bool>,
    /// Task failures charged to each node (drives exclusion).
    node_failures: Vec<u32>,
}

/// A full, owned snapshot of an [`EventSim`]'s mutable state, taken at a
/// conf-sensitivity barrier by the incremental re-pricing pipeline
/// (`engine::fork`): clock, task-event heap, stage-completion heap,
/// locality-hold deque, slot arena with its PS flow remainders and
/// cached rates, per-stage arenas, FAIR pools, round-robin cursor, and
/// the [`SimStats`] counters as of the snapshot.
///
/// Restoring via [`EventSim::resume`] reproduces the core bit for bit:
/// every RNG draw happens at *submission* (the stage arenas carry the
/// already-jittered phases, straggler factors, and clone re-jitters), so
/// there is no live RNG state to capture — the snapshot is pure value
/// state. The checkpoint pins the node count it was taken on; resuming
/// against a different cluster shape is a hard error.
#[derive(Clone)]
pub struct SimCheckpoint {
    nodes: usize,
    policy: SimPolicy,
    discovery: Discovery,
    now: f64,
    free_cores: Vec<i64>,
    free_core_total: i64,
    flows: Vec<Vec<u32>>,
    res_dirty: Vec<bool>,
    dirty: Vec<u32>,
    slots: Vec<Running>,
    free_slots: Vec<u32>,
    live: usize,
    task_heap: TimeHeap,
    completions: TimeHeap,
    holds: VecDeque<(f64, u32)>,
    spec_list: Vec<u32>,
    stages: Vec<StageRt>,
    pending_list: Vec<u32>,
    jobs_running: Vec<usize>,
    pools: Vec<PoolSpec>,
    rr: usize,
    admit_dirty: bool,
    stats: SimStats,
    faults: Option<FaultRt>,
    /// Fault notifications emitted but not yet drained by the engine at
    /// the snapshot (mid-stage snapshots land inside the advance loop,
    /// before the engine's drain) — a resumed run re-delivers them.
    fault_events: Vec<FaultEvent>,
}

impl SimCheckpoint {
    /// Simulated clock at the snapshot.
    pub fn at(&self) -> f64 {
        self.now
    }

    /// Events already processed at the snapshot — the work a resumed
    /// run inherits instead of repeating.
    pub fn events(&self) -> u64 {
        self.stats.events
    }

    /// Handles of stages submitted but not yet completed at the
    /// snapshot (completion still queued).
    pub fn open_stages(&self) -> usize {
        self.stages.len() - self.stats.completions as usize
    }

    /// The policy the snapshot was taken under.
    pub(crate) fn sim_policy(&self) -> SimPolicy {
        self.policy
    }

    /// Approximate heap footprint of the state this snapshot *owns* —
    /// everything except the `Arc`-shared stage arenas ([`StageArena`]),
    /// which are structurally shared (delta-encoded) across every
    /// checkpoint of one recording and accounted separately via
    /// [`arena_chunks`](Self::arena_chunks). Drives the fork stores'
    /// byte budgets.
    pub fn owned_bytes(&self) -> usize {
        use std::mem::size_of;
        let mut b = size_of::<SimCheckpoint>();
        b += self.free_cores.len() * size_of::<i64>();
        b += self
            .flows
            .iter()
            .map(|f| size_of::<Vec<u32>>() + f.len() * size_of::<u32>())
            .sum::<usize>();
        b += self.res_dirty.len();
        b += self.dirty.len() * size_of::<u32>();
        b += self.slots.len() * size_of::<Running>();
        b += self.free_slots.len() * size_of::<u32>();
        b += self.task_heap.bytes();
        b += self.completions.bytes();
        b += self.holds.len() * size_of::<(f64, u32)>();
        b += self.spec_list.len() * size_of::<u32>();
        b += self.pending_list.len() * size_of::<u32>();
        b += self.jobs_running.len() * size_of::<usize>();
        b += self.pools.len() * size_of::<PoolSpec>();
        for st in &self.stages {
            b += size_of::<StageRt>();
            b += st.pending.len() * size_of::<u32>();
            b += st
                .node_buckets
                .iter()
                .map(|q| size_of::<VecDeque<u32>>() + q.len() * size_of::<u32>())
                .sum::<usize>();
            b += st.nopref_queue.len() * size_of::<u32>();
            b += st.in_pending.len() + st.done.len() + st.cloned.len();
            b += (st.task_durations.len() + st.durations_sorted.len()) * size_of::<f64>();
            b += st.orig_queue.len() * size_of::<(u32, u32)>();
            b += st.task_nodes.len() * size_of::<NodeId>();
            b += st.failures.len() * size_of::<u32>();
        }
        if let Some(f) = &self.faults {
            b += f.timeline.len() * size_of::<TimelineEvent>();
            b += f.down.len() + f.excluded.len();
            b += f.node_failures.len() * size_of::<u32>();
        }
        b += self.fault_events.len() * size_of::<FaultEvent>();
        b
    }

    /// `(pointer, bytes)` of each stage's shared phase/preference arena.
    /// Fork stores deduplicate by pointer when accounting a recording's
    /// total footprint: each distinct arena is charged once, however
    /// many checkpoints share it.
    pub fn arena_chunks(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.stages.iter().map(|st| (Arc::as_ptr(&st.arena) as usize, st.arena.bytes()))
    }

    // ---- policy-fork validity facts ----
    //
    // The incremental re-pricer (engine::fork) may resume this snapshot
    // under a *different* locality-wait / speculation policy, provided
    // the recorded prefix would have been bit-identical under both.
    // These predicates certify that from recorded facts alone; each is
    // conservative — `false` only costs a fallback to an earlier
    // checkpoint or a full re-price, never correctness.

    /// No speculation ever perturbed the prefix: no event's clock came
    /// from a threshold crossing and no backup copy was launched. (An
    /// unrealized crossing *is* an event — `next_spec_event` surfaces
    /// the crossing time even when no foreign core is free — so this
    /// also rules out silent candidate state.)
    pub(crate) fn spec_prefix_clean(&self) -> bool {
        self.stats.spec_events == 0 && self.stages.iter().all(|st| st.speculated == 0)
    }

    /// Every submitted stage has all tasks finished (its completion may
    /// still be queued). Required when turning speculation *on* at a
    /// fork: stages submitted under a spec-off policy carry no clone
    /// phase arenas, so only fully-drained prefixes are equivalent.
    pub(crate) fn all_submitted_done(&self) -> bool {
        self.stages.iter().all(|st| st.unfinished == 0)
    }

    /// No task of any *open* stage could have crossed a speculation
    /// threshold of `multiplier` × median at any point in the prefix:
    /// for each stage with recorded durations, the largest elapsed time
    /// any original copy ever reached (finished durations, plus running
    /// originals as of the snapshot clock) stays strictly under
    /// `multiplier` × the smallest finished duration — and medians only
    /// sit above that minimum. Stages with no recorded durations pass
    /// trivially: either no task finished (no median ⇒ no threshold
    /// ever existed) or the stage completed and its durations were
    /// folded into the engine's report — completed stages are the
    /// caller's (engine::fork's) half of this check.
    pub(crate) fn spec_crossing_free(&self, multiplier: f64, overhead: f64) -> bool {
        let mut max_run = vec![0.0f64; self.stages.len()];
        for r in &self.slots {
            if r.alive && !r.is_clone {
                let e = self.now - r.started + overhead;
                let h = r.stage as usize;
                if e > max_run[h] {
                    max_run[h] = e;
                }
            }
        }
        self.stages.iter().enumerate().all(|(h, st)| {
            let mut d_min = f64::INFINITY;
            let mut d_max = 0.0f64;
            for &d in &st.task_durations {
                d_min = d_min.min(d);
                d_max = d_max.max(d);
            }
            if !d_min.is_finite() {
                return true;
            }
            d_max.max(max_run[h]) < multiplier * d_min - EPS
        })
    }

    /// Swapping `locality_wait` from the recorded value to `new_wait`
    /// cannot change the prefix: both waits are positive (zero flips
    /// the admission `expired` flag and the hold-push set wholesale)
    /// and every stage drained its pending queue strictly before the
    /// *smaller* deadline — so every admission decision the prefix ever
    /// made saw an unexpired hold under either wait, and no live hold
    /// deadline ever fired. (Still-pending stages are bounded by the
    /// snapshot clock; post-resume admissions run under the new policy
    /// on both sides.)
    pub(crate) fn locality_fork_ok(&self, new_wait: f64) -> bool {
        let old = self.policy.locality_wait;
        if old.to_bits() == new_wait.to_bits() {
            return true;
        }
        if !(old > 0.0 && new_wait > 0.0) {
            return false;
        }
        let minw = old.min(new_wait);
        self.stages.iter().all(|st| {
            let t_last = if st.pending.is_empty() { st.drained_at } else { self.now };
            t_last + EPS < st.submitted_at + minw
        })
    }

    /// No fault ever perturbed the recorded prefix: no injected task
    /// failure, no executor loss/restart, no abort. The recovery policy
    /// (`spark.task.maxFailures` and friends) is only ever *consulted*
    /// at a failure, so a fault-clean prefix is bit-identical under any
    /// failure-policy values — the certificate behind the re-pricer's
    /// failure-field forks. Trivially true whenever faults are disarmed.
    pub(crate) fn fault_prefix_clean(&self) -> bool {
        self.stats.task_failures == 0
            && self.stats.executor_losses == 0
            && self.stats.executor_restarts == 0
            && self.stats.stage_aborts == 0
    }
}

/// Mid-stage snapshot collector for
/// [`EventSim::advance_observed`]: takes a [`SimCheckpoint`] after
/// every `every`-th winning task finish, until the accumulated *owned*
/// bytes (arena bytes are shared, not owned — see
/// [`SimCheckpoint::owned_bytes`]) exceed `budget_bytes`. A pure
/// observer: attaching one never changes the simulated timeline.
pub struct SnapshotSink {
    every: u64,
    budget_bytes: usize,
    taken_bytes: usize,
    last_finishes: u64,
    out: Vec<SimCheckpoint>,
}

impl SnapshotSink {
    /// Snapshot cadence `every` (in winning task finishes, clamped to
    /// ≥ 1) under an owned-bytes budget.
    pub fn new(every: u64, budget_bytes: usize) -> SnapshotSink {
        SnapshotSink {
            every: every.max(1),
            budget_bytes,
            taken_bytes: 0,
            last_finishes: 0,
            out: Vec::new(),
        }
    }

    /// Owned bytes of the snapshots collected so far.
    pub fn bytes(&self) -> usize {
        self.taken_bytes
    }

    /// Snapshots collected so far.
    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }

    /// Drain the collected snapshots (in event order).
    pub fn take(&mut self) -> Vec<SimCheckpoint> {
        std::mem::take(&mut self.out)
    }

    fn observe(&mut self, sim: &EventSim<'_>) {
        let finishes = sim.stats.task_finishes;
        if finishes < self.last_finishes + self.every || self.taken_bytes >= self.budget_bytes {
            return;
        }
        self.last_finishes = finishes;
        let cp = sim.checkpoint();
        self.taken_bytes += cp.owned_bytes();
        self.out.push(cp);
    }
}

const EPS: f64 = 1e-9;

impl<'a> EventSim<'a> {
    /// A core with the default policy (no locality wait, no speculation)
    /// and indexed discovery.
    pub fn new(cluster: &'a ClusterSpec, scheduler: Box<dyn Scheduler>) -> EventSim<'a> {
        EventSim::with_policy(cluster, scheduler, SimPolicy::default())
    }

    /// A core with explicit delay-scheduling / speculation policy and
    /// indexed discovery.
    pub fn with_policy(
        cluster: &'a ClusterSpec,
        scheduler: Box<dyn Scheduler>,
        policy: SimPolicy,
    ) -> EventSim<'a> {
        EventSim::with_discovery(cluster, scheduler, policy, Discovery::Indexed)
    }

    /// A core with an explicit [`Discovery`] mode — `Scan` is the
    /// self-verifying reference the golden equivalence tests compare
    /// against.
    pub fn with_discovery(
        cluster: &'a ClusterSpec,
        scheduler: Box<dyn Scheduler>,
        policy: SimPolicy,
        discovery: Discovery,
    ) -> EventSim<'a> {
        let nodes = cluster.nodes as usize;
        EventSim {
            cluster,
            scheduler,
            policy,
            discovery,
            now: 0.0,
            free_cores: vec![cluster.cores_per_node as i64; nodes],
            free_core_total: cluster.total_cores() as i64,
            flows: vec![Vec::new(); 2 * nodes],
            res_dirty: vec![false; 2 * nodes],
            dirty: Vec::new(),
            slots: Vec::with_capacity(cluster.total_cores() as usize),
            free_slots: Vec::new(),
            live: 0,
            task_heap: TimeHeap::new(),
            completions: TimeHeap::new(),
            holds: VecDeque::new(),
            spec_list: Vec::new(),
            stages: Vec::new(),
            pending_list: Vec::new(),
            jobs_running: Vec::new(),
            pools: Vec::new(),
            rr: 0,
            admit_dirty: false,
            stats: SimStats::default(),
            finished_scratch: Vec::new(),
            trace: TraceSink::null(),
            stage_spans: Vec::new(),
            faults: None,
            fault_events: Vec::new(),
        }
    }

    /// Arm the fault injector: crash hazards and the loss/restart
    /// timeline from `plan`, recovered under `recovery`. Must be called
    /// before the first submission (stages capture their fault streams
    /// at submit time). Arming an empty plan changes nothing; leaving
    /// faults disarmed is bit-identical to the pre-fault core.
    pub fn arm_faults(&mut self, plan: Arc<FaultPlan>, recovery: RecoveryPolicy) {
        assert!(self.stages.is_empty(), "arm_faults must precede the first submission");
        let nodes = self.free_cores.len();
        let timeline = plan.timeline();
        self.faults = Some(FaultRt {
            plan,
            recovery,
            timeline,
            cursor: 0,
            down: vec![false; nodes],
            excluded: vec![false; nodes],
            node_failures: vec![0; nodes],
        });
    }

    /// Swap the recovery policy on a resumed core (the re-pricer's
    /// failure-policy fork: valid only behind a
    /// [`SimCheckpoint::fault_prefix_clean`] certificate). No-op when
    /// faults are disarmed.
    pub(crate) fn set_recovery(&mut self, recovery: RecoveryPolicy) {
        if let Some(f) = self.faults.as_mut() {
            f.recovery = recovery;
        }
    }

    /// The armed plan, if any (identity check for checkpoint reuse).
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_ref().map(|f| &*f.plan)
    }

    /// Drain the fault/recovery notifications queued since the last
    /// call (empty on fault-free runs).
    pub fn take_fault_events(&mut self) -> Vec<FaultEvent> {
        std::mem::take(&mut self.fault_events)
    }

    /// Attach an observability recorder: task-copy spans (winners,
    /// cancelled losers) and speculation instants are emitted under the
    /// spans bound via [`bind_trace_span`](Self::bind_trace_span). The
    /// recorder is a pure observer — attaching one never changes the
    /// timeline, the results, or the [`SimStats`] counters (pinned by
    /// the observability golden suite).
    pub fn set_trace(&mut self, sink: TraceSink) {
        self.trace = sink;
    }

    /// Bind stage `handle`'s trace span: task-copy events of that stage
    /// are parented under it.
    pub fn bind_trace_span(&mut self, handle: StageHandle, span: SpanId) {
        if self.stage_spans.len() <= handle {
            self.stage_spans.resize(handle + 1, SpanId::NONE);
        }
        self.stage_spans[handle] = span;
    }

    fn stage_span(&self, h: usize) -> SpanId {
        self.stage_spans.get(h).copied().unwrap_or(SpanId::NONE)
    }

    /// Current event-clock time (seconds, simulated).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// The scheduling policy in force.
    pub fn scheduler_name(&self) -> &'static str {
        self.scheduler.name()
    }

    /// The delay-scheduling / speculation policy in force.
    pub fn policy(&self) -> &SimPolicy {
        &self.policy
    }

    /// The event-discovery mode in force.
    pub fn discovery(&self) -> Discovery {
        self.discovery
    }

    /// Snapshot of the core's work counters.
    pub fn stats(&self) -> SimStats {
        self.stats
    }

    /// Snapshot the complete mutable state of the core (see
    /// [`SimCheckpoint`]). Cheap relative to re-pricing: a handful of
    /// `Vec` clones proportional to live state, no recomputation.
    pub fn checkpoint(&self) -> SimCheckpoint {
        SimCheckpoint {
            nodes: self.free_cores.len(),
            policy: self.policy,
            discovery: self.discovery,
            now: self.now,
            free_cores: self.free_cores.clone(),
            free_core_total: self.free_core_total,
            flows: self.flows.clone(),
            res_dirty: self.res_dirty.clone(),
            dirty: self.dirty.clone(),
            slots: self.slots.clone(),
            free_slots: self.free_slots.clone(),
            live: self.live,
            task_heap: self.task_heap.clone(),
            completions: self.completions.clone(),
            holds: self.holds.clone(),
            spec_list: self.spec_list.clone(),
            stages: self.stages.clone(),
            pending_list: self.pending_list.clone(),
            jobs_running: self.jobs_running.clone(),
            pools: self.pools.clone(),
            rr: self.rr,
            admit_dirty: self.admit_dirty,
            stats: self.stats,
            faults: self.faults.clone(),
            fault_events: self.fault_events.clone(),
        }
    }

    /// Rebuild a core from a [`SimCheckpoint`], inheriting the snapshot's
    /// timeline prefix instead of re-processing it. The scheduler is
    /// supplied fresh (it is stateless policy, not value state) and must
    /// match the mode the checkpoint ran under; `cluster` must be the
    /// cluster the checkpoint was taken on — both are enforced upstream
    /// by the fork store's key and checked here where cheap.
    ///
    /// The restored core's [`SimStats`] continue from the snapshot, so
    /// `events` still counts the whole timeline and downstream equality
    /// oracles hold; `replayed_events` records the inherited prefix and
    /// `forked_trials` ticks once — [`SimStats::logical`] projects both
    /// away for bit-identity comparisons against full runs.
    pub fn resume(
        cluster: &'a ClusterSpec,
        scheduler: Box<dyn Scheduler>,
        cp: &SimCheckpoint,
    ) -> EventSim<'a> {
        assert_eq!(
            cluster.nodes as usize,
            cp.nodes,
            "SimCheckpoint belongs to a different cluster shape"
        );
        let mut stats = cp.stats;
        stats.replayed_events = cp.stats.events;
        stats.forked_trials = cp.stats.forked_trials + 1;
        EventSim {
            cluster,
            scheduler,
            policy: cp.policy,
            discovery: cp.discovery,
            now: cp.now,
            free_cores: cp.free_cores.clone(),
            free_core_total: cp.free_core_total,
            flows: cp.flows.clone(),
            res_dirty: cp.res_dirty.clone(),
            dirty: cp.dirty.clone(),
            slots: cp.slots.clone(),
            free_slots: cp.free_slots.clone(),
            live: cp.live,
            task_heap: cp.task_heap.clone(),
            completions: cp.completions.clone(),
            holds: cp.holds.clone(),
            spec_list: cp.spec_list.clone(),
            stages: cp.stages.clone(),
            pending_list: cp.pending_list.clone(),
            jobs_running: cp.jobs_running.clone(),
            pools: cp.pools.clone(),
            rr: cp.rr,
            admit_dirty: cp.admit_dirty,
            stats,
            finished_scratch: Vec::new(),
            trace: TraceSink::null(),
            stage_spans: Vec::new(),
            faults: cp.faults.clone(),
            fault_events: cp.fault_events.clone(),
        }
    }

    /// [`resume`](Self::resume) under a *different* [`SimPolicy`] — the
    /// policy-forking path of the incremental re-pricer. The caller
    /// must have certified the swap through the checkpoint's
    /// fork-validity predicates ([`SimCheckpoint::locality_fork_ok`]
    /// and friends): the recorded prefix must be bit-identical under
    /// both policies. Live locality-hold deadlines are rewritten for
    /// the new wait (deadline = stage submission + wait; submission
    /// times are non-decreasing along the deque, so the rewrite
    /// preserves its sort order); stale entries are observably inert
    /// under either deadline.
    pub(crate) fn resume_with_policy(
        cluster: &'a ClusterSpec,
        scheduler: Box<dyn Scheduler>,
        cp: &SimCheckpoint,
        policy: SimPolicy,
    ) -> EventSim<'a> {
        let mut sim = EventSim::resume(cluster, scheduler, cp);
        if policy.locality_wait.to_bits() != cp.policy.locality_wait.to_bits() {
            for i in 0..sim.holds.len() {
                let h = sim.holds[i].1 as usize;
                sim.holds[i].0 = sim.stages[h].submitted_at + policy.locality_wait;
            }
        }
        sim.policy = policy;
        sim
    }

    /// Assign `job` to a FAIR pool (weight / minShare). May be called
    /// before or after the job's first submission; jobs default to
    /// weight 1 / minShare 0.
    pub fn set_pool(&mut self, job: JobId, pool: PoolSpec) {
        if job >= self.pools.len() {
            self.pools.resize(job + 1, PoolSpec::default());
        }
        self.pools[job] = pool;
    }

    /// Submit a stage of heterogeneous `tasks` on behalf of `job`. CPU
    /// jitter is drawn per task, in task order, from a stream seeded by
    /// `opts.seed`; the straggler tail (`opts.straggler`) and the
    /// speculative-copy re-jitter draw from their own dedicated streams,
    /// so enabling either never perturbs the base draws. Uniform stages
    /// can use the allocation-light [`submit_shaped`](Self::submit_shaped)
    /// instead — the two are bit-identical for equivalent inputs.
    pub fn submit(&mut self, job: JobId, tasks: &[TaskSpec], opts: &SimOpts) -> StageHandle {
        let n = tasks.len();
        let total: usize = tasks.iter().map(|t| t.phases.len()).sum();
        let mut phases = Vec::with_capacity(total);
        let mut phase_off = Vec::with_capacity(n + 1);
        phase_off.push(0u32);
        for t in tasks {
            phases.extend_from_slice(&t.phases);
            phase_off.push(phases.len() as u32);
        }
        let pref_total: usize = tasks.iter().map(|t| t.preferred_nodes.len()).sum();
        let mut preferred = Vec::with_capacity(pref_total);
        let mut pref_off = Vec::with_capacity(n + 1);
        pref_off.push(0u32);
        for t in tasks {
            preferred.extend_from_slice(&t.preferred_nodes);
            pref_off.push(preferred.len() as u32);
        }
        self.submit_arena(job, phases, phase_off, preferred, pref_off, n, opts)
    }

    /// Fast-path submission for uniform stages (see [`StageSpec`]): one
    /// shared phase template, at most one preferred node per task, and a
    /// constant number of allocations regardless of task count.
    pub fn submit_shaped(
        &mut self,
        job: JobId,
        spec: &StageSpec<'_>,
        opts: &SimOpts,
    ) -> StageHandle {
        let n = spec.tasks;
        let p = spec.template.len();
        let mut phases = Vec::with_capacity(n * p);
        for _ in 0..n {
            phases.extend_from_slice(spec.template);
        }
        let phase_off: Vec<u32> = (0..=n).map(|i| (i * p) as u32).collect();
        let (preferred, pref_off) = if spec.preferred.is_empty() {
            (Vec::new(), vec![0u32; n + 1])
        } else {
            // A real assert (not debug-only): a short preference table
            // would otherwise surface as an out-of-bounds slice deep in
            // the admission scan, far from the misuse site.
            let w = spec.pref_width;
            assert!(w > 0, "StageSpec: non-empty preferences need pref_width >= 1");
            assert_eq!(
                spec.preferred.len(),
                n * w,
                "StageSpec: pref_width preferred nodes per task"
            );
            (spec.preferred.to_vec(), (0..=n).map(|i| (i * w) as u32).collect())
        };
        self.submit_arena(job, phases, phase_off, preferred, pref_off, n, opts)
    }

    /// Shared submission core: applies the jitter/straggler/clone draws
    /// to the flat phase arena and registers the stage.
    #[allow(clippy::too_many_arguments)]
    fn submit_arena(
        &mut self,
        job: JobId,
        mut phases: Vec<Phase>,
        phase_off: Vec<u32>,
        preferred: Vec<NodeId>,
        pref_off: Vec<u32>,
        n: usize,
        opts: &SimOpts,
    ) -> StageHandle {
        let mut rng = Prng::new(opts.seed ^ 0xD15C0);
        let mut srng = Prng::new(opts.seed ^ 0x57A6_61E5);
        let spec_on = self.policy.speculation.is_some();
        let mut crng = if spec_on { Some(Prng::new(opts.seed ^ 0xC1_0E5)) } else { None };
        // Clones re-jitter the *unjittered* template (no straggler
        // factor: the backup lands on a healthy node).
        let mut clone_phases: Vec<Phase> = if spec_on { phases.clone() } else { Vec::new() };
        for t in 0..n {
            let range = phase_off[t] as usize..phase_off[t + 1] as usize;
            let mut factor = 1.0 + opts.jitter * (rng.f64() - 0.5) * 2.0;
            if let Some(s) = &opts.straggler {
                if s.prob > 0.0 && srng.f64() < s.prob {
                    factor *= s.factor.max(1.0);
                }
            }
            scale_cpu_in_place(&mut phases[range.clone()], factor);
            if let Some(crng) = crng.as_mut() {
                let cf = 1.0 + opts.jitter * (crng.f64() - 0.5) * 2.0;
                scale_cpu_in_place(&mut clone_phases[range], cf);
            }
        }
        let pending_pref =
            (0..n).filter(|&t| pref_off[t + 1] > pref_off[t]).count();
        let nodes = self.free_cores.len();
        let mut node_buckets = vec![VecDeque::new(); nodes];
        let mut nopref_queue = VecDeque::new();
        for t in 0..n {
            let prefs = &preferred[pref_off[t] as usize..pref_off[t + 1] as usize];
            if prefs.is_empty() {
                nopref_queue.push_back(t as u32);
            } else {
                for &p in prefs {
                    node_buckets[p as usize % nodes].push_back(t as u32);
                }
            }
        }

        // One wave overhead per `total_cores` tasks, charged between the
        // last task finish and the completion event (the engine's
        // downstream stages unlock only then).
        let waves = (n as f64 / self.cluster.total_cores() as f64).ceil().max(1.0);
        let completion_overhead = waves * self.cluster.task_overhead;

        let handle = self.stages.len();
        if job >= self.jobs_running.len() {
            self.jobs_running.resize(job + 1, 0);
        }
        if job >= self.pools.len() {
            self.pools.resize(job + 1, PoolSpec::default());
        }
        self.stages.push(StageRt {
            job,
            seq: handle,
            tasks: n,
            seed: opts.seed,
            failures: vec![0; n],
            aborted: false,
            in_pending_list: n > 0,
            arena: Arc::new(StageArena { phases, clone_phases, phase_off, preferred, pref_off }),
            pending: (0..n as u32).collect(),
            pending_pref,
            node_buckets,
            nopref_queue,
            in_pending: vec![true; n],
            done: vec![false; n],
            cloned: vec![false; n],
            unfinished: n,
            submitted_at: self.now,
            drained_at: if n == 0 { self.now } else { f64::INFINITY },
            task_durations: Vec::with_capacity(n),
            durations_sorted: if spec_on { Vec::with_capacity(n) } else { Vec::new() },
            spec_th: None,
            spec_dirty: true,
            in_spec_list: false,
            orig_queue: VecDeque::new(),
            task_nodes: vec![0; n],
            locality_hits: 0,
            speculated: 0,
            cpu_secs: 0.0,
            disk_bytes: 0.0,
            net_bytes: 0.0,
            completion_overhead,
        });
        if n == 0 {
            self.completions.set(handle as u32, self.now + completion_overhead);
        } else {
            self.pending_list.push(handle as u32);
            if self.policy.locality_wait > 0.0 && pending_pref > 0 {
                // Deadlines are pushed in submission order and `now`
                // never decreases, so the deque stays sorted.
                self.holds.push_back((self.now + self.policy.locality_wait, handle as u32));
            }
        }
        self.admit_dirty = true;
        handle
    }

    /// Advance the clock until the next stage completes; `None` once all
    /// submitted stages have completed (the sim stays usable — submit
    /// more and call again).
    pub fn advance(&mut self) -> Option<StageCompletion> {
        self.advance_observed(None)
    }

    /// [`advance`](Self::advance) with mid-stage snapshotting: after
    /// every `sink.every`-th winning task finish the core checkpoints
    /// itself into `sink` (until its byte budget is spent). The sink is
    /// a pure observer — passing `Some` vs `None` never changes the
    /// timeline, the stats, or the completion stream; the snapshot lands
    /// after the event's finishers are processed and before the next
    /// event is chosen, which is exactly where [`resume`](Self::resume)
    /// re-enters the loop.
    pub fn advance_observed(
        &mut self,
        mut sink: Option<&mut SnapshotSink>,
    ) -> Option<StageCompletion> {
        loop {
            if let Some(c) = self.pop_due_completion() {
                return Some(c);
            }
            self.admit();
            self.speculate();
            // Roll dirty resources so every deadline is fresh, then pick
            // the earliest event across the four queues.
            self.sweep_dirty();
            let (next, from_spec) = self.next_event_time();
            if next == f64::INFINITY {
                debug_assert!(self.live == 0, "idle core with {} copies still running", self.live);
                return None;
            }
            let prev_now = self.now;
            self.now = next.max(self.now);
            self.stats.events += 1;
            if from_spec {
                self.stats.spec_events += 1;
            }
            self.stats.live_copy_event_sum += self.live as u64;
            self.drain_holds(prev_now);
            // Losses/restarts due at this instant apply before task
            // finishes: a copy finishing exactly at its node's loss is
            // lost with the node (killed and re-queued, not finished).
            self.apply_due_faults();
            self.collect_and_process();
            if let Some(s) = sink.as_deref_mut() {
                s.observe(self);
            }
        }
    }

    /// Run every submitted stage to completion, returning completions in
    /// event order.
    pub fn drain(&mut self) -> Vec<StageCompletion> {
        let mut out = Vec::new();
        while let Some(c) = self.advance() {
            out.push(c);
        }
        out
    }

    // ---- event discovery ----

    /// Re-roll every flow on a dirty resource: advance `remaining` under
    /// the old cached rate, install the new fair-share rate, and re-key
    /// the predicted finish time. Exact — rates only change at events,
    /// and every membership change marks its resource dirty.
    fn sweep_dirty(&mut self) {
        while let Some(res) = self.dirty.pop() {
            let res = res as usize;
            self.res_dirty[res] = false;
            let count = self.flows[res].len();
            if count == 0 {
                continue;
            }
            let rate = self.res_cap(res) / count as f64;
            for k in 0..count {
                let slot = self.flows[res][k];
                let r = &mut self.slots[slot as usize];
                r.remaining -= r.rate * (self.now - r.updated_at);
                r.updated_at = self.now;
                r.rate = rate;
                let dl = self.now + r.remaining / rate;
                r.deadline = dl;
                self.stats.flow_rolls += 1;
                if self.discovery == Discovery::Indexed {
                    if self.task_heap.set(slot, dl) {
                        self.stats.heap_pushes += 1;
                    } else {
                        self.stats.heap_updates += 1;
                    }
                }
            }
        }
    }

    /// Earliest upcoming event time across task deadlines, stage
    /// completions, hold expiries, and speculation-threshold crossings;
    /// `INFINITY` when fully idle. The flag is `true` iff the winning
    /// time came *strictly* from a speculation crossing — both discovery
    /// modes compare the same four sources in the same order, so the
    /// attribution (and the [`SimStats::spec_events`] counter it feeds)
    /// is mode-invariant.
    fn next_event_time(&mut self) -> (f64, bool) {
        let mut next = f64::INFINITY;
        match self.discovery {
            Discovery::Indexed => {
                if let Some((t, _)) = self.task_heap.peek() {
                    next = t;
                }
            }
            Discovery::Scan => {
                self.verify_flow_invariants();
                for r in &self.slots {
                    if r.alive && r.deadline < next {
                        next = r.deadline;
                    }
                }
            }
        }
        if let Some((t, _)) = self.completions.peek() {
            if t < next {
                next = t;
            }
        }
        if self.policy.locality_wait > 0.0 {
            // Front entries that are stage-stale (nothing pending, or no
            // pending task still carries a preference) or already crossed
            // can never set `admit_dirty` again — prune them for good.
            while let Some(&(dl, h)) = self.holds.front() {
                let s = &self.stages[h as usize];
                if s.pending_pref == 0 || s.pending.is_empty() || dl <= self.now + EPS {
                    self.holds.pop_front();
                    continue;
                }
                if dl < next {
                    next = dl;
                }
                break;
            }
        }
        let spec_next = self.next_spec_event();
        let mut from_spec = false;
        if spec_next < next {
            next = spec_next;
            from_spec = true;
        }
        // The fault timeline competes like any other event source, in
        // both discovery modes identically (ties go to the earlier
        // candidate above — the loss still applies before that event's
        // finishers are processed).
        if let Some(f) = &self.faults {
            if let Some(ev) = f.timeline.get(f.cursor) {
                if ev.at() < next {
                    next = ev.at();
                    from_spec = false;
                }
            }
        }
        (next, from_spec)
    }

    /// Earliest future speculation-threshold crossing. Within a stage,
    /// crossings (`started + th − overhead`) are non-decreasing along
    /// the launch-ordered original queue, so the walk skips stale
    /// entries and originals that have *already* crossed (they are
    /// standing candidates awaiting a foreign free core, not future
    /// events) and stops at the first future crossing — the stage's
    /// minimum.
    fn next_spec_event(&mut self) -> f64 {
        let Some(spec) = self.policy.speculation else { return f64::INFINITY };
        let overhead = self.cluster.task_overhead;
        let mut best = f64::INFINITY;
        let mut i = 0;
        while i < self.spec_list.len() {
            let h = self.spec_list[i] as usize;
            self.prune_orig_queue(h);
            if self.stages[h].orig_queue.is_empty() {
                self.stages[h].in_spec_list = false;
                self.spec_list.swap_remove(i);
                continue;
            }
            if let Some(th) = self.stage_spec_threshold(h, &spec) {
                let st = &self.stages[h];
                for &(slot, ti) in st.orig_queue.iter() {
                    if !self.orig_entry_live(h, slot, ti) {
                        continue; // stale mid-queue entry
                    }
                    let t = self.slots[slot as usize].started + th - overhead;
                    if t > self.now + EPS {
                        if t < best {
                            best = t;
                        }
                        break; // deeper originals cross even later
                    }
                    // Already crossed: a standing clone candidate, not a
                    // future event — keep looking for the next crossing.
                }
            }
            i += 1;
        }
        best
    }

    /// The stage's cached speculation threshold, recomputed only when a
    /// task of the stage finished since the last read. In `Scan` mode
    /// the cache is cross-checked against a fresh computation.
    fn stage_spec_threshold(&mut self, h: usize, spec: &SpecPolicy) -> Option<f64> {
        let st = &mut self.stages[h];
        if st.spec_dirty {
            st.spec_dirty = false;
            st.spec_th = compute_spec_threshold(st, spec);
        }
        let th = st.spec_th;
        if self.discovery == Discovery::Scan {
            let fresh = compute_spec_threshold(&self.stages[h], spec);
            assert_eq!(
                fresh.map(f64::to_bits),
                th.map(f64::to_bits),
                "stale speculation-threshold cache on stage {h}"
            );
        }
        th
    }

    /// Drop stale front entries of a stage's original queue: finished or
    /// cloned tasks, and recycled slots (validated against the slot's
    /// current occupant).
    fn prune_orig_queue(&mut self, h: usize) {
        loop {
            let Some(&(slot, ti)) = self.stages[h].orig_queue.front() else { return };
            if self.orig_entry_live(h, slot, ti) {
                return;
            }
            self.stages[h].orig_queue.pop_front();
        }
    }

    /// A queue entry is live while its slot still holds the same
    /// original copy and the task is neither done nor cloned.
    fn orig_entry_live(&self, h: usize, slot: u32, ti: u32) -> bool {
        let r = &self.slots[slot as usize];
        r.alive
            && r.stage as usize == h
            && r.task_idx == ti
            && !r.is_clone
            && !self.stages[h].done[ti as usize]
            && !self.stages[h].cloned[ti as usize]
    }

    /// Scan-mode cross-check of the dirty-resource rule: after the
    /// sweep, every live flow's cached rate must equal a fresh
    /// fair-share recomputation, bit for bit.
    fn verify_flow_invariants(&self) {
        for res in 0..self.flows.len() {
            let count = self.flows[res].len();
            if count == 0 {
                continue;
            }
            let rate = self.res_cap(res) / count as f64;
            for (k, &slot) in self.flows[res].iter().enumerate() {
                let r = &self.slots[slot as usize];
                assert!(r.alive && r.is_ps, "flow list holds a dead or non-PS slot {slot}");
                assert_eq!(r.res_pos as usize, k, "flow back-pointer out of sync");
                assert_eq!(
                    r.rate.to_bits(),
                    rate.to_bits(),
                    "stale fair-share rate on res {res}: a membership change missed its dirty mark"
                );
            }
        }
    }

    /// After the clock moved, consume hold deadlines crossed by this
    /// event; a crossed hold on a stage that is still holding tasks
    /// re-triggers the admission scan (the stage just degraded to ANY).
    fn drain_holds(&mut self, prev_now: f64) {
        if self.policy.locality_wait <= 0.0 {
            return;
        }
        while let Some(&(dl, h)) = self.holds.front() {
            if dl > self.now + EPS {
                break;
            }
            self.holds.pop_front();
            let s = &self.stages[h as usize];
            if dl > prev_now + EPS && s.pending_pref > 0 && !s.pending.is_empty() {
                self.admit_dirty = true;
            }
        }
    }

    // ---- event processing ----

    /// Collect every copy whose deadline is due and process it (phase
    /// transition or task finish), in ascending slot order — the
    /// canonical same-event processing order shared by both discovery
    /// modes.
    fn collect_and_process(&mut self) {
        let cutoff = self.now + EPS;
        let mut finished = std::mem::take(&mut self.finished_scratch);
        finished.clear();
        match self.discovery {
            Discovery::Indexed => {
                // Minimum-timestamp ties (and same-epsilon stragglers)
                // come out in one batched fix-up pass, not per-event
                // pop/sift cycles; the sort restores the canonical
                // ascending-slot processing order.
                let popped = self.task_heap.pop_due_into(cutoff, &mut finished);
                self.stats.heap_pops += popped as u64;
                finished.sort_unstable();
            }
            Discovery::Scan => {
                for (id, r) in self.slots.iter().enumerate() {
                    if r.alive && r.deadline <= cutoff {
                        finished.push(id as u32);
                    }
                }
            }
        }
        // Mark the whole batch before processing: a same-event sibling
        // tie must resolve through the moot path (the first-processed
        // copy wins; `cancel_sibling` skips collected slots).
        for &slot in &finished {
            self.slots[slot as usize].collected = true;
        }
        for &slot in &finished {
            self.process_finished(slot);
        }
        self.finished_scratch = finished;
    }

    /// One copy's current phase ended: release its PS membership, detect
    /// moot copies (the sibling won at this very event), then enter the
    /// next phase or finish the task.
    fn process_finished(&mut self, slot: u32) {
        self.slots[slot as usize].collected = false;
        self.end_flow(slot);
        let (h, ti, node, started) = {
            let r = &self.slots[slot as usize];
            (r.stage as usize, r.task_idx as usize, r.node, r.started)
        };
        if self.stages[h].done[ti] || self.stages[h].aborted {
            self.free_slot(slot);
            self.give_core(node);
            self.jobs_running[self.stages[h].job] -= 1;
            return;
        }
        self.slots[slot as usize].phase_idx += 1;
        if !self.enter_next_phase(slot) {
            let (sibling, is_clone, doomed) = {
                let r = &self.slots[slot as usize];
                (r.sibling, r.is_clone, r.doomed)
            };
            self.free_slot(slot);
            if doomed {
                self.fail_task(h, ti, node, started, is_clone, sibling);
            } else {
                self.finish_task(h, ti, node, started, sibling, is_clone);
            }
        }
    }

    /// Start the copy's next non-noop phase; `false` when its phases are
    /// exhausted. NaN-valued phases are treated as noops — see
    /// [`Phase::is_noop`].
    fn enter_next_phase(&mut self, slot: u32) -> bool {
        loop {
            let (h, ti, pi, is_clone) = {
                let r = &self.slots[slot as usize];
                (r.stage as usize, r.task_idx as usize, r.phase_idx as usize, r.is_clone)
            };
            let p = {
                let st = &self.stages[h];
                let phases =
                    if is_clone { st.clone_task_phases(ti) } else { st.task_phases(ti) };
                match phases.get(pi) {
                    Some(p) => *p,
                    None => return false,
                }
            };
            if p.is_noop() {
                self.slots[slot as usize].phase_idx += 1;
                continue;
            }
            self.stats.phase_transitions += 1;
            match p {
                Phase::Cpu { secs } => {
                    let d = secs / self.cluster.cpu_speed;
                    self.stages[h].cpu_secs += d;
                    let dl = self.now + d;
                    let r = &mut self.slots[slot as usize];
                    r.is_ps = false;
                    r.is_cpu = true;
                    r.deadline = dl;
                    self.heap_set(slot, dl);
                }
                Phase::Fixed { secs } => {
                    let dl = self.now + secs;
                    let r = &mut self.slots[slot as usize];
                    r.is_ps = false;
                    r.is_cpu = false;
                    r.deadline = dl;
                    self.heap_set(slot, dl);
                }
                Phase::DiskRead { bytes } | Phase::DiskWrite { bytes } => {
                    self.start_flow(slot, ResKind::Disk, bytes);
                }
                Phase::NetIn { bytes } => {
                    self.start_flow(slot, ResKind::Nic, bytes);
                }
            }
            return true;
        }
    }

    /// Join the slot's node-local resource as a new PS flow. The flow's
    /// rate and deadline are installed by the dirty sweep before the
    /// next discovery.
    fn start_flow(&mut self, slot: u32, kind: ResKind, bytes: f64) {
        let (node, h) = {
            let r = &self.slots[slot as usize];
            (r.node as usize, r.stage as usize)
        };
        match kind {
            ResKind::Disk => self.stages[h].disk_bytes += bytes,
            ResKind::Nic => self.stages[h].net_bytes += bytes,
        }
        let res = self.res_index(node, kind);
        let pos = self.flows[res].len() as u32;
        self.flows[res].push(slot);
        {
            let r = &mut self.slots[slot as usize];
            r.is_ps = true;
            r.is_cpu = false;
            r.res = kind;
            r.remaining = bytes;
            r.updated_at = self.now;
            r.rate = 0.0;
            r.deadline = f64::INFINITY;
            r.res_pos = pos;
        }
        self.mark_dirty(res);
        self.heap_set(slot, f64::INFINITY);
    }

    /// Withdraw the slot from its resource's flow list (no-op for
    /// fixed-rate phases) and mark the resource dirty.
    fn end_flow(&mut self, slot: u32) {
        let (is_ps, node, kind, pos) = {
            let r = &self.slots[slot as usize];
            (r.is_ps, r.node as usize, r.res, r.res_pos as usize)
        };
        if !is_ps {
            return;
        }
        self.slots[slot as usize].is_ps = false;
        let res = self.res_index(node, kind);
        debug_assert_eq!(self.flows[res][pos], slot);
        self.flows[res].swap_remove(pos);
        if let Some(&moved) = self.flows[res].get(pos) {
            self.slots[moved as usize].res_pos = pos as u32;
        }
        self.mark_dirty(res);
    }

    /// The winning copy of `stage`'s task `ti` finished on `node`
    /// (started at `started`; `sibling` is the winner's recorded racing
    /// partner, if a backup was launched). Cancels the losing sibling,
    /// if it is still running.
    fn finish_task(
        &mut self,
        h: usize,
        ti: usize,
        node: NodeId,
        started: f64,
        sibling: u32,
        is_clone: bool,
    ) {
        if self.trace.enabled() {
            let name = if is_clone {
                format!("task {ti} (clone won)")
            } else {
                format!("task {ti}")
            };
            self.trace.span(self.stage_span(h), "task", &name, started, self.now);
        }
        self.give_core(node);
        self.stats.task_finishes += 1;
        let job = self.stages[h].job;
        self.jobs_running[job] -= 1;
        let dur = self.now - started + self.cluster.task_overhead;
        let spec_on = self.policy.speculation.is_some();
        let had_clone = {
            let st = &mut self.stages[h];
            st.done[ti] = true;
            st.task_nodes[ti] = node;
            st.task_durations.push(dur);
            if spec_on {
                let i = st.durations_sorted.partition_point(|&x| x < dur);
                st.durations_sorted.insert(i, dur);
                st.spec_dirty = true;
            }
            st.unfinished -= 1;
            st.cloned[ti]
        };
        if self.stages[h].unfinished == 0 {
            let due = self.now + self.stages[h].completion_overhead;
            self.completions.set(h as u32, due);
        }
        if had_clone {
            self.cancel_sibling(h, ti, sibling);
        }
    }

    /// First-finisher-wins: cancel the still-running sibling copy of a
    /// speculated task — free its core, withdraw its processor-shared
    /// flow mid-stream, and refund the stage's meters for the work the
    /// loser never completed (phases it never entered were never
    /// metered). `slot` is the winner's recorded sibling link, validated
    /// here because the loser may have finished at this very event
    /// (collected ⇒ handled as a moot finisher, no refunds) or already
    /// been recycled.
    fn cancel_sibling(&mut self, h: usize, ti: usize, slot: u32) {
        if slot == SLOT_NONE {
            return;
        }
        {
            let r = &self.slots[slot as usize];
            if !r.alive || r.collected || r.stage as usize != h || r.task_idx as usize != ti {
                return; // the sibling finished at this same event: moot
            }
        }
        let (is_ps, is_cpu, kind, node, left) = {
            let r = &self.slots[slot as usize];
            let left = if r.is_ps {
                // Roll the loser's flow to now before refunding (its
                // resource may have been clean — and unrolled — for a
                // while).
                (r.remaining - r.rate * (self.now - r.updated_at)).max(0.0)
            } else {
                (r.deadline - self.now).max(0.0)
            };
            (r.is_ps, r.is_cpu, r.res, r.node, left)
        };
        if is_ps {
            match kind {
                ResKind::Disk => self.stages[h].disk_bytes -= left,
                ResKind::Nic => self.stages[h].net_bytes -= left,
            }
            self.end_flow(slot);
        } else if is_cpu {
            self.stages[h].cpu_secs -= left;
        }
        if self.trace.enabled() {
            let started = self.slots[slot as usize].started;
            self.trace.span(
                self.stage_span(h),
                "task",
                &format!("task {ti} (cancelled)"),
                started,
                self.now,
            );
        }
        self.free_slot(slot);
        self.give_core(node);
        self.jobs_running[self.stages[h].job] -= 1;
    }

    // ---- fault injection & recovery ----

    /// Apply every timeline entry due at the current clock (losses sort
    /// before restarts at the same instant — see
    /// [`FaultPlan::timeline`]). Runs after the clock moves and before
    /// the event's finishers are processed, in both discovery modes.
    fn apply_due_faults(&mut self) {
        loop {
            let ev = {
                let Some(f) = &self.faults else { return };
                match f.timeline.get(f.cursor) {
                    Some(&e) if e.at() <= self.now + EPS => e,
                    _ => return,
                }
            };
            self.faults.as_mut().expect("injector armed").cursor += 1;
            match ev {
                TimelineEvent::Lost { node, .. } => self.apply_node_loss(node),
                TimelineEvent::Restarted { node, .. } => self.apply_node_restart(node),
            }
        }
    }

    /// An executor/node went down: its free cores leave placement, every
    /// copy running on it is killed (meters refunded for work never
    /// completed), and each killed task with no surviving racing copy
    /// re-queues — *without* charging `spark.task.maxFailures` (Spark
    /// treats executor loss as infrastructure, not task fault). Finished
    /// shuffle-map outputs on the node are the engine's problem: it
    /// receives [`FaultEvent::ExecutorLost`] and drives the FetchFailed
    /// resubmission path.
    fn apply_node_loss(&mut self, node: NodeId) {
        let counted = {
            let f = self.faults.as_mut().expect("fault timeline without injector");
            if f.down[node as usize] {
                return; // lost twice without a restart between
            }
            f.down[node as usize] = true;
            !f.excluded[node as usize]
        };
        self.stats.executor_losses += 1;
        if counted {
            let freed = self.free_cores[node as usize];
            self.free_cores[node as usize] = 0;
            self.free_core_total -= freed;
        }
        self.fault_events.push(FaultEvent::ExecutorLost { node, at: self.now });
        if self.trace.enabled() {
            self.trace.instant(
                SpanId::NONE,
                "executor",
                &format!("executor lost: node {node}"),
                self.now,
            );
        }
        for slot in 0..self.slots.len() as u32 {
            let (alive, collected, on_node, h, ti, sibling) = {
                let r = &self.slots[slot as usize];
                (
                    r.alive,
                    r.collected,
                    r.node == node,
                    r.stage as usize,
                    r.task_idx as usize,
                    r.sibling,
                )
            };
            if !alive || collected || !on_node {
                continue;
            }
            self.kill_copy(slot, "lost with executor");
            if self.stages[h].done[ti] || self.stages[h].aborted {
                continue;
            }
            let sibling_live = sibling != SLOT_NONE && {
                let r = &self.slots[sibling as usize];
                r.alive && r.stage as usize == h && r.task_idx as usize == ti
            };
            if sibling_live {
                continue; // the racing copy on another node carries on
            }
            self.requeue_task(h, ti);
            self.stats.task_retries += 1;
        }
    }

    /// A down node's *compute* comes back (its lost shuffle outputs do
    /// not). Excluded nodes stay out of placement even after a restart.
    fn apply_node_restart(&mut self, node: NodeId) {
        let restore = {
            let f = self.faults.as_mut().expect("fault timeline without injector");
            if !f.down[node as usize] {
                return;
            }
            f.down[node as usize] = false;
            !f.excluded[node as usize]
        };
        self.stats.executor_restarts += 1;
        if restore {
            let cores = self.cluster.cores_per_node as i64;
            self.free_cores[node as usize] = cores;
            self.free_core_total += cores;
            self.admit_dirty = true;
        }
        self.fault_events.push(FaultEvent::ExecutorRestarted { node, at: self.now });
        if self.trace.enabled() {
            self.trace.instant(
                SpanId::NONE,
                "executor",
                &format!("executor restarted: node {node}"),
                self.now,
            );
        }
    }

    /// A doomed copy reached its commit point and failed: charge the
    /// task's failure count (and the node's, for exclusion), then —
    /// unless a racing copy survives — retry the task up to
    /// `spark.task.maxFailures` or abort its stage past the limit. The
    /// caller has already freed the copy's slot.
    fn fail_task(
        &mut self,
        h: usize,
        ti: usize,
        node: NodeId,
        started: f64,
        is_clone: bool,
        sibling: u32,
    ) {
        if self.trace.enabled() {
            let name = if is_clone {
                format!("task {ti} (clone failed)")
            } else {
                format!("task {ti} (failed)")
            };
            self.trace.span(self.stage_span(h), "task", &name, started, self.now);
        }
        self.give_core(node);
        self.stats.task_failures += 1;
        self.jobs_running[self.stages[h].job] -= 1;
        self.stages[h].failures[ti] += 1;
        let failures = self.stages[h].failures[ti];
        self.fault_events.push(FaultEvent::TaskFailed {
            stage: h,
            task: ti as u32,
            node,
            at: self.now,
            failures,
        });
        self.note_node_failure(node);
        let sibling_live = sibling != SLOT_NONE && {
            let r = &self.slots[sibling as usize];
            r.alive && r.stage as usize == h && r.task_idx as usize == ti
        };
        if sibling_live {
            return; // the racing copy may still win the task
        }
        let max = self
            .faults
            .as_ref()
            .map(|f| f.recovery.max_task_failures)
            .expect("doomed copy without injector");
        if failures >= max {
            self.abort_stage(h);
        } else {
            self.requeue_task(h, ti);
            self.stats.task_retries += 1;
        }
    }

    /// Charge a task failure to `node`; past
    /// `spark.excludeOnFailure.task.maxTaskAttemptsPerNode` (with
    /// exclusion enabled) the node leaves placement for good.
    fn note_node_failure(&mut self, node: NodeId) {
        let exclude = {
            let Some(f) = self.faults.as_mut() else { return };
            f.node_failures[node as usize] += 1;
            f.recovery.exclude_on_failure
                && !f.excluded[node as usize]
                && f.node_failures[node as usize] >= f.recovery.max_task_attempts_per_node
        };
        if exclude {
            self.exclude_node(node);
        }
    }

    /// Remove `node` from placement permanently: zero its free cores
    /// (running copies keep their cores until they retire — gated
    /// [`give_core`](Self::give_core) swallows them).
    fn exclude_node(&mut self, node: NodeId) {
        let was_down = {
            let f = self.faults.as_mut().expect("exclusion without injector");
            f.excluded[node as usize] = true;
            f.down[node as usize]
        };
        if !was_down {
            let freed = self.free_cores[node as usize];
            self.free_cores[node as usize] = 0;
            self.free_core_total -= freed;
        }
        self.fault_events.push(FaultEvent::NodeExcluded { node, at: self.now });
        if self.trace.enabled() {
            self.trace.instant(
                SpanId::NONE,
                "exclusion",
                &format!("node {node} excluded"),
                self.now,
            );
        }
    }

    /// Put a failed or executor-lost task back in its stage's pending
    /// structures (sorted re-insertion everywhere — the ascending-index
    /// invariants behind bucketed admission and `binary_search` must
    /// hold for re-entrants too). The stage re-enters `pending_list` if
    /// it had drained. No fresh locality hold is granted: hold windows
    /// are measured from stage submission (the same deterministic
    /// simplification delay scheduling already makes), so a retry after
    /// the window launches ANY immediately.
    fn requeue_task(&mut self, h: usize, ti: usize) {
        if self.stages[h].in_pending[ti] {
            return; // already pending (defensive: double requeue)
        }
        let nodes = self.free_cores.len();
        let has_pref = self.stages[h].task_has_pref(ti);
        let t = ti as u32;
        {
            let st = &mut self.stages[h];
            if let Err(pos) = st.pending.binary_search(&t) {
                st.pending.insert(pos, t);
            }
            st.in_pending[ti] = true;
            if has_pref {
                st.pending_pref += 1;
            }
            // The stage is no longer drained; conservative for the
            // locality-fork certificate (it falls back to the clock).
            st.drained_at = f64::INFINITY;
        }
        if has_pref {
            let arena = Arc::clone(&self.stages[h].arena);
            let prefs =
                &arena.preferred[arena.pref_off[ti] as usize..arena.pref_off[ti + 1] as usize];
            let st = &mut self.stages[h];
            for &p in prefs {
                let q = &mut st.node_buckets[p as usize % nodes];
                // Sorted re-insert; a not-yet-pruned stale entry of this
                // task simply becomes live again.
                if let Err(pos) = q.binary_search(&t) {
                    q.insert(pos, t);
                }
            }
        } else {
            let q = &mut self.stages[h].nopref_queue;
            if let Err(pos) = q.binary_search(&t) {
                q.insert(pos, t);
            }
        }
        if !self.stages[h].in_pending_list {
            self.stages[h].in_pending_list = true;
            let hv = h as u32;
            let pos = self.pending_list.binary_search(&hv).unwrap_or_else(|e| e);
            self.pending_list.insert(pos, hv);
        }
        self.admit_dirty = true;
    }

    /// A task exhausted `spark.task.maxFailures`: the whole stage aborts
    /// *now* — every running copy is killed, pending work is cleared,
    /// and the completion (flagged [`StageCompletion::aborted`]) fires
    /// immediately so the engine can crash the owning job.
    fn abort_stage(&mut self, h: usize) {
        self.stats.stage_aborts += 1;
        self.stages[h].aborted = true;
        self.fault_events.push(FaultEvent::StageAborted { stage: h, at: self.now });
        if self.trace.enabled() {
            self.trace.instant(
                self.stage_span(h),
                "abort",
                &format!("stage {h} aborted (task exceeded maxFailures)"),
                self.now,
            );
        }
        for slot in 0..self.slots.len() as u32 {
            let (alive, collected, of_stage) = {
                let r = &self.slots[slot as usize];
                (r.alive, r.collected, r.stage as usize == h)
            };
            // Collected siblings are mid-batch: process_finished retires
            // them through the aborted-stage guard instead.
            if alive && !collected && of_stage {
                self.kill_copy(slot, "stage aborted");
            }
        }
        {
            let st = &mut self.stages[h];
            for &t in st.pending.iter() {
                st.in_pending[t as usize] = false;
            }
            st.pending.clear();
            st.pending_pref = 0;
            st.nopref_queue.clear();
            for q in st.node_buckets.iter_mut() {
                q.clear();
            }
            st.orig_queue.clear();
            st.unfinished = 0;
        }
        self.completions.set(h as u32, self.now);
    }

    /// Forcibly retire a running copy (node loss, stage abort): refund
    /// the stage's meters for work it never completed — exactly as
    /// [`cancel_sibling`](Self::cancel_sibling) refunds a losing racer —
    /// withdraw its flow, and release its core and slot.
    fn kill_copy(&mut self, slot: u32, reason: &str) {
        let (h, ti, node, started, is_ps, is_cpu, kind, left) = {
            let r = &self.slots[slot as usize];
            let left = if r.is_ps {
                (r.remaining - r.rate * (self.now - r.updated_at)).max(0.0)
            } else {
                (r.deadline - self.now).max(0.0)
            };
            (
                r.stage as usize,
                r.task_idx as usize,
                r.node,
                r.started,
                r.is_ps,
                r.is_cpu,
                r.res,
                left,
            )
        };
        if is_ps {
            match kind {
                ResKind::Disk => self.stages[h].disk_bytes -= left,
                ResKind::Nic => self.stages[h].net_bytes -= left,
            }
            self.end_flow(slot);
        } else if is_cpu {
            self.stages[h].cpu_secs -= left;
        }
        if self.trace.enabled() {
            self.trace.span(
                self.stage_span(h),
                "task",
                &format!("task {ti} ({reason})"),
                started,
                self.now,
            );
        }
        self.free_slot(slot);
        self.give_core(node);
        self.jobs_running[self.stages[h].job] -= 1;
    }

    /// Emit the earliest stage completion due at the current clock
    /// (ties: lowest handle, by the heap's id tie-break).
    fn pop_due_completion(&mut self) -> Option<StageCompletion> {
        let (due, h) = self.completions.peek()?;
        if due > self.now + EPS {
            return None;
        }
        self.completions.pop();
        self.stats.completions += 1;
        let st = &mut self.stages[h as usize];
        let stats = StageStats {
            duration: due - st.submitted_at,
            task_time: Summary::from(std::mem::take(&mut st.task_durations)),
            cpu_secs: st.cpu_secs,
            disk_bytes: st.disk_bytes,
            net_bytes: st.net_bytes,
            tasks: st.tasks,
            locality_hits: st.locality_hits,
            speculated: st.speculated,
        };
        Some(StageCompletion {
            handle: h as usize,
            job: st.job,
            at: due,
            stats,
            task_nodes: std::mem::take(&mut st.task_nodes),
            aborted: st.aborted,
        })
    }

    // ---- admission & speculation ----

    /// The stage's first admissible pending task under the current free
    /// cores: a task launches NODE_LOCAL when one of its preferred nodes
    /// has a free core; a task with no preference — or one whose stage's
    /// locality hold has expired — takes any free core (the caller
    /// guarantees one exists). Tasks still holding for busy local nodes
    /// are skipped: that is delay scheduling. Returns
    /// `(queue position, task index, Some(local node) | None for ANY)`.
    ///
    /// Discovery is bucketed: each free node probes its *own* bucket's
    /// front (lazily pruned) instead of the whole pending queue, so a
    /// held stage costs O(free nodes) per offer rather than O(pending).
    /// The pending queue is ascending by task index (tasks never
    /// re-enter), so the earliest admissible task is the minimum over
    /// bucket fronts — identical, pick for pick, to the linear scan,
    /// which [`Discovery::Scan`] re-runs and asserts against.
    fn find_admissible(&mut self, h: usize) -> Option<(usize, usize, Option<NodeId>)> {
        let nodes = self.free_cores.len();
        let expired = {
            let st = &self.stages[h];
            self.policy.locality_wait <= 0.0
                || self.now + EPS >= st.submitted_at + self.policy.locality_wait
        };
        // Lowest-indexed pending task with a free preferred node.
        let mut local: Option<u32> = None;
        for node in 0..nodes {
            if self.free_cores[node] <= 0 {
                continue;
            }
            let st = &mut self.stages[h];
            while let Some(&ti) = st.node_buckets[node].front() {
                self.stats.admit_probes += 1;
                if st.in_pending[ti as usize] {
                    break;
                }
                st.node_buckets[node].pop_front();
            }
            if let Some(&ti) = st.node_buckets[node].front() {
                if local.map_or(true, |best| ti < best) {
                    local = Some(ti);
                }
            }
        }
        // Lowest-indexed task allowed an ANY launch: any pending task
        // once the hold expired, otherwise only preference-free ones.
        let any: Option<u32> = if expired {
            self.stages[h].pending.front().copied()
        } else {
            let st = &mut self.stages[h];
            while let Some(&ti) = st.nopref_queue.front() {
                self.stats.admit_probes += 1;
                if st.in_pending[ti as usize] {
                    break;
                }
                st.nopref_queue.pop_front();
            }
            st.nopref_queue.front().copied()
        };
        // An ANY candidate ahead of the local one cannot itself have a
        // free preferred node (it would have been a bucket front below
        // `local`), so it launches ANY exactly as the linear scan does.
        let pick: Option<(u32, Option<NodeId>)> = match (local, any) {
            (Some(l), Some(a)) if a < l => Some((a, None)),
            (Some(l), _) => {
                let st = &self.stages[h];
                let n = st
                    .task_prefs(l as usize)
                    .iter()
                    .copied()
                    .find(|&n| self.free_cores[n as usize % nodes] > 0)
                    .expect("bucketed local candidate has a free preferred node");
                Some((l, Some((n as usize % nodes) as NodeId)))
            }
            (None, Some(a)) => Some((a, None)),
            (None, None) => None,
        };
        let out = pick.map(|(ti, node)| {
            let pos = self
                .stages[h]
                .pending
                .binary_search(&ti)
                .expect("picked task is pending (pending is ascending)");
            (pos, ti as usize, node)
        });
        if self.discovery == Discovery::Scan {
            let linear = find_admissible_linear(&self.stages[h], &self.free_cores, expired);
            assert_eq!(
                out, linear,
                "bucketed admission diverged from the linear reference on stage {h}"
            );
        }
        out
    }

    /// Fill free cores from pending stages, in scheduler order, honoring
    /// per-task locality (delay scheduling).
    fn admit(&mut self) {
        if !self.admit_dirty {
            return;
        }
        self.admit_dirty = false;
        loop {
            if self.free_core_total <= 0 {
                break;
            }
            // Per-stage admissible picks under the current free cores and
            // locality state; `pending_list` keeps the scan to stages
            // that still have pending tasks.
            let mut candidates: Vec<StageView> = Vec::new();
            let mut picks: Vec<(usize, usize, Option<NodeId>)> = Vec::new();
            let mut i = 0;
            while i < self.pending_list.len() {
                let h = self.pending_list[i] as usize;
                if self.stages[h].pending.is_empty() {
                    self.pending_list.remove(i); // keeps ascending handle order
                    self.stages[h].in_pending_list = false;
                    continue;
                }
                if let Some(pick) = self.find_admissible(h) {
                    let s = &self.stages[h];
                    let pool = self.pools.get(s.job).copied().unwrap_or_default();
                    candidates.push(StageView {
                        handle: h,
                        job: s.job,
                        seq: s.seq,
                        pending: s.pending.len(),
                        job_running: self.jobs_running[s.job],
                        weight: pool.weight,
                        min_share: pool.min_share,
                    });
                    picks.push(pick);
                }
                i += 1;
            }
            if candidates.is_empty() {
                break;
            }
            let Some(h) = self.scheduler.pick(&candidates) else {
                break;
            };
            let ci = candidates
                .iter()
                .position(|c| c.handle == h)
                .expect("scheduler picked a non-candidate stage");
            let (pos, ti, local) = picks[ci];
            {
                let now = self.now;
                let st = &mut self.stages[h];
                let removed = st.pending.remove(pos).expect("pick position is valid");
                debug_assert_eq!(removed as usize, ti);
                st.in_pending[ti] = false;
                if st.task_has_pref(ti) {
                    st.pending_pref -= 1;
                }
                if st.pending.is_empty() {
                    st.drained_at = now;
                }
            }
            let (node, is_local) = match local {
                Some(n) => (n, true),
                None => (self.pick_node_any(), false),
            };
            if is_local {
                self.stages[h].locality_hits += 1;
            }
            self.launch_copy(h, ti, node, false, SLOT_NONE);
        }
    }

    /// Launch one task copy (original or speculative clone) on `node`:
    /// takes the core, allocates a slot, links the speculation-race
    /// sibling (clones pass the original's slot in `sibling`), registers
    /// speculation bookkeeping, and enters the first phase. Zero-work
    /// copies finish on the spot.
    fn launch_copy(&mut self, h: usize, ti: usize, node: NodeId, is_clone: bool, sibling: u32) {
        self.free_cores[node as usize] -= 1;
        self.free_core_total -= 1;
        self.jobs_running[self.stages[h].job] += 1;
        self.stats.task_launches += 1;
        let doomed = match &self.faults {
            Some(f) => {
                let st = &self.stages[h];
                f.plan.dooms(st.seed, ti as u32, st.failures[ti], is_clone, node)
            }
            None => false,
        };
        let slot = self.alloc_slot(Running {
            stage: h as u32,
            task_idx: ti as u32,
            node,
            phase_idx: 0,
            res_pos: 0,
            started: self.now,
            deadline: f64::INFINITY,
            remaining: 0.0,
            updated_at: self.now,
            rate: 0.0,
            is_ps: false,
            res: ResKind::Disk,
            is_cpu: false,
            is_clone,
            doomed,
            alive: true,
            collected: false,
            sibling,
        });
        if sibling != SLOT_NONE {
            // Back-link the original so whichever copy wins can cancel
            // the other in O(1).
            self.slots[sibling as usize].sibling = slot;
        }
        if !is_clone && self.policy.speculation.is_some() {
            let st = &mut self.stages[h];
            st.orig_queue.push_back((slot, ti as u32));
            if !st.in_spec_list {
                st.in_spec_list = true;
                self.spec_list.push(h as u32);
            }
        }
        if !self.enter_next_phase(slot) {
            // Zero-work copy: wins (or finishes — or, doomed, fails)
            // immediately.
            let sib = self.slots[slot as usize].sibling;
            self.free_slot(slot);
            if doomed {
                self.fail_task(h, ti, node, self.now, is_clone, sib);
            } else {
                self.finish_task(h, ti, node, self.now, sib, is_clone);
            }
        }
    }

    /// Launch backup copies of stragglers: for every stage past its
    /// speculation quantile, any running original whose elapsed time
    /// exceeds multiplier × the median successful duration is cloned onto
    /// a *different* node (first finisher wins; see `cancel_sibling`).
    /// At most one backup per task. The launch-ordered original queues
    /// make candidate discovery O(candidates) instead of O(running).
    fn speculate(&mut self) {
        let Some(spec) = self.policy.speculation else { return };
        if self.free_core_total <= 0 {
            return;
        }
        let overhead = self.cluster.task_overhead;
        let mut cands: Vec<(usize, usize, NodeId, u32)> = Vec::new();
        let mut i = 0;
        while i < self.spec_list.len() {
            let h = self.spec_list[i] as usize;
            self.prune_orig_queue(h);
            if self.stages[h].orig_queue.is_empty() {
                self.stages[h].in_spec_list = false;
                self.spec_list.swap_remove(i);
                continue;
            }
            if let Some(th) = self.stage_spec_threshold(h, &spec) {
                let st = &self.stages[h];
                for &(slot, ti) in st.orig_queue.iter() {
                    let r = &self.slots[slot as usize];
                    let live = r.alive
                        && r.stage as usize == h
                        && r.task_idx == ti
                        && !r.is_clone
                        && !st.done[ti as usize]
                        && !st.cloned[ti as usize];
                    if !live {
                        continue; // stale mid-queue entry
                    }
                    if self.now - r.started + overhead >= th - EPS {
                        cands.push((h, ti as usize, r.node, slot));
                    } else {
                        // `started` is non-decreasing along the queue, so
                        // every deeper original is younger — none past
                        // the threshold.
                        break;
                    }
                }
            }
            i += 1;
        }
        // (h, ti) is unique per candidate, so the node/slot tail of the
        // sort key never decides an ordering.
        cands.sort_unstable();
        for (h, ti, orig_node, orig_slot) in cands {
            // A backup must land on a different machine than the copy it
            // races; if none has a free core, retry at a later event.
            let Some(node) = self.pick_node_excluding(orig_node) else { continue };
            {
                let st = &mut self.stages[h];
                st.cloned[ti] = true;
                st.speculated += 1;
            }
            if self.trace.enabled() {
                self.trace.instant(
                    self.stage_span(h),
                    "speculation",
                    &format!("speculate task {ti} -> node {node}"),
                    self.now,
                );
            }
            self.launch_copy(h, ti, node, true, orig_slot);
            if self.free_core_total <= 0 {
                break;
            }
        }
    }

    // ---- slots, cores, resources ----

    fn alloc_slot(&mut self, r: Running) -> u32 {
        self.live += 1;
        if let Some(slot) = self.free_slots.pop() {
            self.slots[slot as usize] = r;
            slot
        } else {
            self.slots.push(r);
            (self.slots.len() - 1) as u32
        }
    }

    fn free_slot(&mut self, slot: u32) {
        debug_assert!(self.slots[slot as usize].alive);
        self.slots[slot as usize].alive = false;
        self.free_slots.push(slot);
        self.live -= 1;
        if self.discovery == Discovery::Indexed {
            self.task_heap.remove(slot);
        }
    }

    /// Return a core to `node` and re-arm the admission scan. Down and
    /// excluded nodes swallow the core instead: their capacity is out of
    /// placement until restart (exclusion is permanent), and their
    /// `free_cores` entry stays zero so every placement scan skips them
    /// without fault-specific checks.
    fn give_core(&mut self, node: NodeId) {
        if let Some(f) = &self.faults {
            if f.down[node as usize] || f.excluded[node as usize] {
                return;
            }
        }
        self.free_cores[node as usize] += 1;
        self.free_core_total += 1;
        self.admit_dirty = true;
    }

    fn heap_set(&mut self, slot: u32, key: f64) {
        if self.discovery != Discovery::Indexed {
            return;
        }
        if self.task_heap.set(slot, key) {
            self.stats.heap_pushes += 1;
        } else {
            self.stats.heap_updates += 1;
        }
    }

    fn res_index(&self, node: usize, kind: ResKind) -> usize {
        match kind {
            ResKind::Disk => node,
            ResKind::Nic => self.free_cores.len() + node,
        }
    }

    fn res_cap(&self, res: usize) -> f64 {
        if res < self.free_cores.len() {
            self.cluster.disk_bw
        } else {
            self.cluster.net_bw
        }
    }

    fn mark_dirty(&mut self, res: usize) {
        if !self.res_dirty[res] {
            self.res_dirty[res] = true;
            self.dirty.push(res as u32);
        }
    }

    /// Round-robin scan for any free core. Call only when one exists.
    fn pick_node_any(&mut self) -> NodeId {
        let nodes = self.free_cores.len();
        for k in 0..nodes {
            let cand = (self.rr + k) % nodes;
            if self.free_cores[cand] > 0 {
                self.rr = (cand + 1) % nodes;
                return cand as NodeId;
            }
        }
        unreachable!("pick_node_any called with no free core")
    }

    /// Round-robin scan for a free core on any node other than `exclude`
    /// (speculative copies must race from a different machine).
    fn pick_node_excluding(&mut self, exclude: NodeId) -> Option<NodeId> {
        let nodes = self.free_cores.len();
        for k in 0..nodes {
            let cand = (self.rr + k) % nodes;
            if cand as NodeId != exclude && self.free_cores[cand] > 0 {
                self.rr = (cand + 1) % nodes;
                return Some(cand as NodeId);
            }
        }
        None
    }
}

/// Reference admission scan (the pre-bucket algorithm): walk the whole
/// pending queue in order and apply the locality rules per task. The
/// bucketed [`EventSim::find_admissible`] must agree pick for pick;
/// [`Discovery::Scan`] asserts it on every offer.
fn find_admissible_linear(
    st: &StageRt,
    free_cores: &[i64],
    expired: bool,
) -> Option<(usize, usize, Option<NodeId>)> {
    let nodes = free_cores.len();
    for (pos, &ti) in st.pending.iter().enumerate() {
        let prefs = st.task_prefs(ti as usize);
        if let Some(&n) = prefs.iter().find(|&&n| free_cores[n as usize % nodes] > 0) {
            return Some((pos, ti as usize, Some((n as usize % nodes) as NodeId)));
        }
        if prefs.is_empty() || expired {
            return Some((pos, ti as usize, None));
        }
    }
    None
}

/// Scale the CPU phases of one task's slice of the phase arena by
/// `factor` (jitter and the straggler tail apply to compute, not to I/O
/// volumes — bytes moved are a property of the data, not of the
/// executor's health).
fn scale_cpu_in_place(phases: &mut [Phase], factor: f64) {
    for p in phases {
        if let Phase::Cpu { secs } = p {
            *secs *= factor;
        }
    }
}

/// The stage's speculation threshold: `multiplier × median successful
/// duration`, or `None` while fewer than `quantile` of its tasks are
/// done (Spark's `minFinishedForSpeculation`). The median is the upper
/// median (Spark's `durations(medianIndex)`), read off the incrementally
/// sorted duration list.
fn compute_spec_threshold(st: &StageRt, spec: &SpecPolicy) -> Option<f64> {
    let n = st.tasks;
    if n == 0 || st.arena.clone_phases.is_empty() {
        return None;
    }
    let done = n - st.unfinished;
    let min_done = ((spec.quantile * n as f64).ceil() as usize).max(1);
    if done < min_done {
        return None;
    }
    debug_assert_eq!(st.durations_sorted.len(), done);
    Some(spec.multiplier * st.durations_sorted[st.durations_sorted.len() / 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet() -> ClusterSpec {
        let mut c = ClusterSpec::mini();
        c.task_overhead = 0.0;
        c
    }

    fn opts0() -> SimOpts {
        SimOpts { jitter: 0.0, seed: 1, straggler: None }
    }

    fn cpu_tasks(n: usize, secs: f64) -> Vec<TaskSpec> {
        (0..n).map(|_| TaskSpec::new(vec![Phase::Cpu { secs }])).collect()
    }

    // ---- the indexed queue itself ----

    #[test]
    fn time_heap_orders_updates_and_removals() {
        let mut h = TimeHeap::new();
        assert!(h.peek().is_none());
        assert!(h.set(3, 5.0));
        assert!(h.set(1, 2.0));
        assert!(h.set(7, 9.0));
        assert_eq!(h.peek(), Some((2.0, 1)));
        // decrease-key moves an entry to the front...
        assert!(!h.set(7, 1.0));
        assert_eq!(h.peek(), Some((1.0, 7)));
        // ...increase-key pushes it back down.
        assert!(!h.set(7, 10.0));
        assert_eq!(h.pop(), Some((2.0, 1)));
        h.remove(3);
        h.remove(3); // double-remove is a no-op
        assert_eq!(h.pop(), Some((10.0, 7)));
        assert!(h.pop().is_none());
        assert_eq!(h.len(), 0);
    }

    #[test]
    fn time_heap_ties_break_on_id() {
        let mut h = TimeHeap::new();
        for id in [9u32, 4, 6, 1] {
            h.set(id, 3.25);
        }
        let order: Vec<u32> = std::iter::from_fn(|| h.pop().map(|(_, id)| id)).collect();
        assert_eq!(order, vec![1, 4, 6, 9], "equal keys must pop in id order");
    }

    #[test]
    fn time_heap_matches_naive_min_under_random_ops() {
        let mut h = TimeHeap::new();
        let mut naive: Vec<(u32, f64)> = Vec::new();
        let mut rng = Prng::new(0xBEEF);
        for _ in 0..2000 {
            let id = rng.below(64) as u32;
            match rng.below(3) {
                0 | 1 => {
                    let key = rng.f64() * 100.0;
                    h.set(id, key);
                    if let Some(e) = naive.iter_mut().find(|(i, _)| *i == id) {
                        e.1 = key;
                    } else {
                        naive.push((id, key));
                    }
                }
                _ => {
                    h.remove(id);
                    naive.retain(|(i, _)| *i != id);
                }
            }
            let expect = naive
                .iter()
                .map(|&(i, k)| (k, i))
                .min_by(|a, b| {
                    a.0.partial_cmp(&b.0).unwrap().then_with(|| a.1.cmp(&b.1))
                });
            assert_eq!(h.peek(), expect);
            assert_eq!(h.len(), naive.len());
            assert!(naive.iter().all(|&(i, _)| h.contains(i)));
        }
    }

    // ---- scheduling semantics (indexed core) ----

    #[test]
    fn two_stages_interleave_on_shared_cores() {
        // 8 cores; two stages of 8 × 1 s submitted together under FAIR:
        // each job gets 4 cores → both finish at t = 2.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.submit(0, &cpu_tasks(8, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(8, 1.0), &opts0());
        let done = sim.drain();
        assert_eq!(done.len(), 2);
        for d in &done {
            assert!((d.at - 2.0).abs() < 1e-9, "fair finish at {}", d.at);
        }
    }

    #[test]
    fn fifo_prioritizes_the_earlier_job() {
        // Same two stages under FIFO: job 0 takes all 8 cores and
        // finishes at t = 1; job 1 runs after, finishing at t = 2.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &cpu_tasks(8, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(8, 1.0), &opts0());
        let done = sim.drain();
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].job, 0);
        assert!((done[0].at - 1.0).abs() < 1e-9, "{}", done[0].at);
        assert_eq!(done[1].job, 1);
        assert!((done[1].at - 2.0).abs() < 1e-9, "{}", done[1].at);
    }

    #[test]
    fn submission_mid_flight_shares_the_disk() {
        // Job 0 writes 100 MB alone on node 0 (disk 100 MB/s). Drain it,
        // then submit two concurrent writers on the same node: they share
        // the disk and take 2 s.
        let mut c = quiet();
        c.disk_bw = 100.0e6;
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &[TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0)], &opts0());
        let first = sim.advance().unwrap();
        assert!((first.at - 1.0).abs() < 1e-6);
        sim.submit(
            1,
            &[
                TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0),
                TaskSpec::new(vec![Phase::DiskWrite { bytes: 100e6 }]).on(0),
            ],
            &opts0(),
        );
        let second = sim.advance().unwrap();
        assert!((second.at - 3.0).abs() < 1e-6, "{}", second.at);
        assert!(sim.advance().is_none());
    }

    #[test]
    fn completion_waits_for_wave_overhead() {
        let mut c = quiet();
        c.task_overhead = 0.5;
        // 16 tasks on 8 cores → 2 waves → completion at 2×1s + 2×0.5s.
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(0, &cpu_tasks(16, 1.0), &opts0());
        let done = sim.advance().unwrap();
        assert!((done.at - 3.0).abs() < 1e-9, "{}", done.at);
        assert!((done.stats.duration - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stage_completes_immediately() {
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        let h = sim.submit(0, &[], &opts0());
        let done = sim.advance().unwrap();
        assert_eq!(done.handle, h);
        assert!(done.at < 1e-9);
        assert_eq!(done.stats.tasks, 0);
        assert!(done.task_nodes.is_empty());
        assert!(sim.advance().is_none());
    }

    #[test]
    fn scheduler_mode_parses() {
        assert_eq!(SchedulerMode::from_config_name("fifo"), Some(SchedulerMode::Fifo));
        assert_eq!(SchedulerMode::from_config_name("FAIR"), Some(SchedulerMode::Fair));
        assert_eq!(SchedulerMode::from_config_name("fair "), Some(SchedulerMode::Fair));
        assert_eq!(SchedulerMode::from_config_name("lottery"), None);
        assert_eq!(SchedulerMode::Fifo.config_name(), "FIFO");
        assert_eq!(scheduler_for(SchedulerMode::Fair).name(), "FAIR");
    }

    #[test]
    fn event_core_is_deterministic_across_runs() {
        let c = ClusterSpec::mini();
        let mk = || {
            let mut sim = EventSim::new(&c, Box::new(FairScheduler));
            for j in 0..3usize {
                let tasks: Vec<TaskSpec> = (0..10)
                    .map(|i| {
                        TaskSpec::new(vec![
                            Phase::Cpu { secs: 0.1 + (i % 3) as f64 * 0.05 },
                            Phase::DiskWrite { bytes: 2e6 },
                            Phase::NetIn { bytes: 1e6 },
                        ])
                    })
                    .collect();
                sim.submit(
                    j,
                    &tasks,
                    &SimOpts { jitter: 0.08, seed: 7 + j as u64, straggler: None },
                );
            }
            sim.drain().iter().map(|d| (d.handle, d.at)).collect::<Vec<_>>()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "event core must reproduce bit-identically");
    }

    #[test]
    fn nan_phases_are_noops() {
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        sim.submit(
            0,
            &[TaskSpec::new(vec![
                Phase::Cpu { secs: f64::NAN },
                Phase::DiskRead { bytes: f64::NAN },
                Phase::Cpu { secs: 1.0 },
            ])],
            &opts0(),
        );
        let done = sim.advance().unwrap();
        assert!(done.at.is_finite(), "NaN phases must not poison the clock");
        assert!((done.at - 1.0).abs() < 1e-9, "{}", done.at);
    }

    // ---- task-granular features: delay scheduling ----

    #[test]
    fn delay_scheduling_holds_then_degrades() {
        // 3 × 1 s CPU tasks all preferring node 0 (2 cores). Two run
        // locally at t=0; the third:
        //   wait=0   → degrades immediately, runs remotely, stage = 1.0 s
        //   wait=0.5 → holds 0.5 s, then runs remotely, stage = 1.5 s
        //   wait=2   → holds until a local core frees at t=1, stage = 2.0 s
        let c = quiet();
        let run_with = |wait: f64| {
            let mut sim = EventSim::with_policy(
                &c,
                Box::new(FifoScheduler),
                SimPolicy { locality_wait: wait, speculation: None },
            );
            let tasks: Vec<TaskSpec> =
                (0..3).map(|_| TaskSpec::new(vec![Phase::Cpu { secs: 1.0 }]).on(0)).collect();
            sim.submit(0, &tasks, &opts0());
            let done = sim.advance().unwrap();
            assert!(sim.advance().is_none());
            (done.at, done.stats.locality_hits)
        };
        let (t0, h0) = run_with(0.0);
        assert!((t0 - 1.0).abs() < 1e-9, "wait=0 must not hold: {t0}");
        assert_eq!(h0, 2);
        let (t1, h1) = run_with(0.5);
        assert!((t1 - 1.5).abs() < 1e-9, "held 0.5 s then ran remotely: {t1}");
        assert_eq!(h1, 2);
        let (t2, h2) = run_with(2.0);
        assert!((t2 - 2.0).abs() < 1e-9, "patient wait keeps the task local: {t2}");
        assert_eq!(h2, 3, "all three tasks NODE_LOCAL under a patient wait");
    }

    #[test]
    fn held_stage_cedes_cores_to_other_jobs() {
        // Job 0 hogs node 0; job 1's task holds for node 0 under a long
        // locality wait, so job 2's preference-free task must take the
        // idle node-1 core instead of queuing behind job 1's FIFO
        // priority — the point of delay scheduling.
        let mut c = quiet();
        c.nodes = 2;
        c.cores_per_node = 1;
        let mut sim = EventSim::with_policy(
            &c,
            Box::new(FifoScheduler),
            SimPolicy { locality_wait: 10.0, speculation: None },
        );
        sim.submit(0, &[TaskSpec::new(vec![Phase::Cpu { secs: 5.0 }]).on(0)], &opts0());
        sim.submit(1, &[TaskSpec::new(vec![Phase::Cpu { secs: 1.0 }]).on(0)], &opts0());
        sim.submit(2, &[TaskSpec::new(vec![Phase::Cpu { secs: 1.0 }])], &opts0());
        let done = sim.drain();
        let j2 = done.iter().find(|d| d.job == 2).unwrap();
        assert!((j2.at - 1.0).abs() < 1e-9, "job 2 must take the idle node at t=0: {}", j2.at);
        let j0 = done.iter().find(|d| d.job == 0).unwrap();
        assert!((j0.at - 5.0).abs() < 1e-9, "{}", j0.at);
        let j1 = done.iter().find(|d| d.job == 1).unwrap();
        assert!((j1.at - 6.0).abs() < 1e-9, "job 1 holds for its local core: {}", j1.at);
        assert_eq!(j1.stats.locality_hits, 1, "the held task launches NODE_LOCAL");
    }

    // ---- task-granular features: speculative execution ----

    #[test]
    fn speculative_copy_escapes_a_contended_disk() {
        // Node 0's disk (100 MB/s) is hogged by a 1 GB reader (job 1).
        // Job 0 has a quick CPU task and a 100 MB read pinned to node 0.
        // Without speculation the read shares the disk at 50 MB/s and
        // takes 2 s; with speculation a backup copy launches on another
        // node at t=0.2 (median 0.1 s × multiplier 2), reads alone at
        // 100 MB/s, and wins at t=1.2. The loser's flow is cancelled, so
        // the hog accelerates (10.6 s vs 11.0 s) and job 0's disk meter
        // is refunded for the 40 MB the loser never read.
        let mut c = quiet();
        c.disk_bw = 100.0e6;
        let run_with = |spec_on: bool| {
            let policy = SimPolicy {
                locality_wait: 0.0,
                speculation: spec_on
                    .then_some(SpecPolicy { quantile: 0.5, multiplier: 2.0 }),
            };
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            sim.submit(
                1,
                &[TaskSpec::new(vec![Phase::DiskRead { bytes: 1000e6 }]).on(0)],
                &opts0(),
            );
            sim.submit(
                0,
                &[
                    TaskSpec::new(vec![Phase::Cpu { secs: 0.1 }]).on(1),
                    TaskSpec::new(vec![Phase::DiskRead { bytes: 100e6 }]).on(0),
                ],
                &opts0(),
            );
            sim.drain()
        };

        let off = run_with(false);
        let off0 = off.iter().find(|d| d.job == 0).unwrap();
        let off1 = off.iter().find(|d| d.job == 1).unwrap();
        assert!((off0.at - 2.0).abs() < 1e-6, "shared read: {}", off0.at);
        assert!((off1.at - 11.0).abs() < 1e-6, "hog without cancel: {}", off1.at);
        assert_eq!(off0.stats.speculated, 0);

        let on = run_with(true);
        let on0 = on.iter().find(|d| d.job == 0).unwrap();
        let on1 = on.iter().find(|d| d.job == 1).unwrap();
        assert!((on0.at - 1.2).abs() < 1e-6, "backup copy wins at 1.2 s: {}", on0.at);
        assert_eq!(on0.stats.speculated, 1);
        assert!((on1.at - 10.6).abs() < 1e-6, "hog accelerates after cancel: {}", on1.at);
        // Meter refund: 100 MB original − 40 MB never read + 100 MB clone.
        assert!(
            (on0.stats.disk_bytes - 160e6).abs() < 1.0,
            "loser's unread bytes refunded: {}",
            on0.stats.disk_bytes
        );
        // The winning copy's node is recorded for locality parentage.
        assert_ne!(on0.task_nodes[1], 0, "winner ran off node 0");
    }

    #[test]
    fn speculation_is_a_noop_without_stragglers() {
        // Healthy cluster, ±4 % jitter: no task exceeds 1.5 × median, so
        // enabling speculation changes nothing — same clock, no clones.
        let c = ClusterSpec::mini();
        let opts = SimOpts { jitter: 0.04, seed: 42, straggler: None };
        let mk = |policy: SimPolicy| {
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            sim.submit(0, &cpu_tasks(16, 1.0), &opts);
            let done = sim.advance().unwrap();
            (done.at, done.stats.speculated)
        };
        let (off, _) = mk(SimPolicy::default());
        let (on, clones) = mk(SimPolicy {
            locality_wait: 0.0,
            speculation: Some(SpecPolicy { quantile: 0.75, multiplier: 1.5 }),
        });
        assert_eq!(clones, 0);
        assert!((on - off).abs() < 1e-12, "speculation must be free on a healthy stage");
    }

    #[test]
    fn straggler_tail_triggers_clones_and_recovers() {
        // All-straggler probability on one task out of 16: prob high
        // enough that the tail exists, speculation on → the stage must
        // beat the speculation-off run and launch at least one clone.
        let c = quiet();
        let opts = SimOpts {
            jitter: 0.02,
            seed: 7,
            straggler: Some(super::super::Straggler { prob: 0.5, factor: 10.0 }),
        };
        // A low quantile so healthy finishers unlock speculation even
        // when around half the tasks straggle.
        let mk = |spec: Option<SpecPolicy>| {
            let mut sim = EventSim::with_policy(
                &c,
                Box::new(FifoScheduler),
                SimPolicy { locality_wait: 0.0, speculation: spec },
            );
            sim.submit(0, &cpu_tasks(16, 1.0), &opts);
            let done = sim.advance().unwrap();
            (done.at, done.stats.speculated)
        };
        let (off, _) = mk(None);
        let (on, clones) = mk(Some(SpecPolicy { quantile: 0.12, multiplier: 1.5 }));
        assert!(clones > 0, "stragglers must be speculated");
        assert!(
            on < off * 0.6,
            "speculation must recover the straggler tail: on {on:.2}s vs off {off:.2}s"
        );
        // Determinism: repeat bit-identically.
        let (on2, clones2) = mk(Some(SpecPolicy { quantile: 0.12, multiplier: 1.5 }));
        assert_eq!(on, on2);
        assert_eq!(clones, clones2);
    }

    #[test]
    fn crossing_behind_a_blocked_front_original_still_fires() {
        // Regression: speculation events must not stop at the front of
        // the launch-ordered queue. Setup (2 nodes × 2 cores, all
        // originals straggle 4×, clones healthy): a blocker job pins one
        // node-1 core for 100 s; the main job runs two 1 s quorum tasks,
        // straggler A (100 s, node 0) and straggler B (10 s, node 1,
        // launched at t=1). A crosses the 2 s threshold at t=2 but can
        // never clone (the only free core is on its own node); B crosses
        // at t=3 — that crossing must fire as an event even though A
        // sits uncloneable at the queue front. Then: B's healthy clone
        // (2.5 s) wins at 5.5, freeing a node-1 core, A's clone wins at
        // 30.5, and the stage completes at 30.5 with 2 clones. A core
        // that only watches queue fronts idles until B's original
        // finishes at t=11 and completes at 36 instead.
        let mut c = quiet();
        c.nodes = 2;
        c.cores_per_node = 2;
        let opts = SimOpts {
            jitter: 0.0,
            seed: 5,
            straggler: Some(super::super::Straggler { prob: 1.0, factor: 4.0 }),
        };
        for discovery in [Discovery::Scan, Discovery::Indexed] {
            let mut sim = EventSim::with_discovery(
                &c,
                Box::new(FifoScheduler),
                SimPolicy {
                    locality_wait: 0.0,
                    speculation: Some(SpecPolicy { quantile: 0.4, multiplier: 2.0 }),
                },
                discovery,
            );
            sim.submit(0, &[TaskSpec::new(vec![Phase::Cpu { secs: 25.0 }]).on(1)], &opts);
            sim.submit(
                1,
                &[
                    TaskSpec::new(vec![Phase::Cpu { secs: 0.25 }]).on(0),
                    TaskSpec::new(vec![Phase::Cpu { secs: 0.25 }]).on(1),
                    TaskSpec::new(vec![Phase::Cpu { secs: 25.0 }]).on(0), // A
                    TaskSpec::new(vec![Phase::Cpu { secs: 2.5 }]).on(1),  // B
                ],
                &opts,
            );
            let done = sim.drain();
            let main = done.iter().find(|d| d.job == 1).unwrap();
            assert_eq!(main.stats.speculated, 2, "{discovery:?}: both stragglers clone");
            assert!(
                (main.at - 30.5).abs() < 1e-9,
                "{discovery:?}: B's masked crossing must fire at t=3 \
                 (clone chain completes at 30.5, not 36): {}",
                main.at
            );
            let blocker = done.iter().find(|d| d.job == 0).unwrap();
            assert!((blocker.at - 100.0).abs() < 1e-9, "{}", blocker.at);
        }
    }

    // ---- task-granular features: weighted FAIR pools ----

    #[test]
    fn fair_pools_honor_weight() {
        // 8 cores, 16 × 1 s tasks per job; weight 3 vs 1 → 6/2 core
        // split → weighted job at t=3, the other at t=4 (hand-traced).
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.set_pool(0, PoolSpec { weight: 3.0, min_share: 0 });
        sim.submit(0, &cpu_tasks(16, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(16, 1.0), &opts0());
        let done = sim.drain();
        let j0 = done.iter().find(|d| d.job == 0).unwrap().at;
        let j1 = done.iter().find(|d| d.job == 1).unwrap().at;
        assert!((j0 - 3.0).abs() < 1e-9, "weight-3 pool finishes at {j0}");
        assert!((j1 - 4.0).abs() < 1e-9, "weight-1 pool finishes at {j1}");
    }

    #[test]
    fn fair_pools_honor_min_share() {
        // Job 1 holds minShare 6 of the 8 cores: it is "needy" until it
        // runs 6 tasks, mirroring the weight trace → j1 at t=3, j0 at t=4.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.set_pool(1, PoolSpec { weight: 1.0, min_share: 6 });
        sim.submit(0, &cpu_tasks(16, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(16, 1.0), &opts0());
        let done = sim.drain();
        let j0 = done.iter().find(|d| d.job == 0).unwrap().at;
        let j1 = done.iter().find(|d| d.job == 1).unwrap().at;
        assert!((j1 - 3.0).abs() < 1e-9, "minShare-6 pool finishes at {j1}");
        assert!((j0 - 4.0).abs() < 1e-9, "default pool finishes at {j0}");
    }

    #[test]
    fn default_pools_reduce_to_even_shares() {
        // Without explicit pools the weighted comparator must reproduce
        // fewest-running-first: two identical jobs split 4/4 and tie.
        let c = quiet();
        let mut sim = EventSim::new(&c, Box::new(FairScheduler));
        sim.submit(0, &cpu_tasks(8, 1.0), &opts0());
        sim.submit(1, &cpu_tasks(8, 1.0), &opts0());
        for d in sim.drain() {
            assert!((d.at - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn task_granular_features_compose_deterministically() {
        // Locality wait + speculation + stragglers + FAIR pools, three
        // jobs: two runs must agree bit for bit.
        let c = ClusterSpec::mini();
        let mk = || {
            let mut sim = EventSim::with_policy(
                &c,
                Box::new(FairScheduler),
                SimPolicy {
                    locality_wait: 0.3,
                    speculation: Some(SpecPolicy { quantile: 0.6, multiplier: 1.3 }),
                },
            );
            sim.set_pool(1, PoolSpec { weight: 2.0, min_share: 2 });
            for j in 0..3usize {
                let tasks: Vec<TaskSpec> = (0..12)
                    .map(|i| {
                        TaskSpec::new(vec![
                            Phase::Cpu { secs: 0.2 + (i % 4) as f64 * 0.03 },
                            Phase::DiskWrite { bytes: 3e6 },
                        ])
                        .on((i % 4) as NodeId)
                    })
                    .collect();
                sim.submit(
                    j,
                    &tasks,
                    &SimOpts {
                        jitter: 0.05,
                        seed: 11 + j as u64,
                        straggler: Some(super::super::Straggler { prob: 0.2, factor: 6.0 }),
                    },
                );
            }
            sim.drain()
                .iter()
                .map(|d| (d.handle, d.at, d.stats.speculated, d.stats.locality_hits))
                .collect::<Vec<_>>()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "composed features must reproduce bit-identically");
    }

    // ---- the hot-path overhaul's own contracts ----

    /// Drain a core in each discovery mode over the same submissions and
    /// compare the full completion streams bitwise.
    fn drain_both(
        c: &ClusterSpec,
        policy: SimPolicy,
        fair: bool,
        submit: impl Fn(&mut EventSim<'_>),
    ) -> (Vec<StageCompletion>, SimStats, Vec<StageCompletion>, SimStats) {
        let mk = || -> Box<dyn Scheduler> {
            if fair { Box::new(FairScheduler) } else { Box::new(FifoScheduler) }
        };
        let mut scan = EventSim::with_discovery(c, mk(), policy, Discovery::Scan);
        submit(&mut scan);
        let scan_done = scan.drain();
        let scan_stats = scan.stats();
        let mut idx = EventSim::with_discovery(c, mk(), policy, Discovery::Indexed);
        submit(&mut idx);
        let idx_done = idx.drain();
        let idx_stats = idx.stats();
        (scan_done, scan_stats, idx_done, idx_stats)
    }

    fn assert_streams_identical(a: &[StageCompletion], b: &[StageCompletion]) {
        assert_eq!(a.len(), b.len(), "completion counts diverged");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.handle, y.handle);
            assert_eq!(x.job, y.job);
            assert_eq!(x.at.to_bits(), y.at.to_bits(), "stage {} clock diverged", x.handle);
            assert_eq!(x.stats.duration.to_bits(), y.stats.duration.to_bits());
            assert_eq!(x.stats.cpu_secs.to_bits(), y.stats.cpu_secs.to_bits());
            assert_eq!(x.stats.disk_bytes.to_bits(), y.stats.disk_bytes.to_bits());
            assert_eq!(x.stats.net_bytes.to_bits(), y.stats.net_bytes.to_bits());
            assert_eq!(x.stats.locality_hits, y.stats.locality_hits);
            assert_eq!(x.stats.speculated, y.stats.speculated);
            assert_eq!(x.task_nodes, y.task_nodes);
        }
    }

    #[test]
    fn indexed_discovery_matches_scan_reference_bitwise() {
        // Everything on at once: locality holds, speculation, straggler
        // tail, FAIR pools, mixed CPU/disk/NIC phases across three jobs.
        let c = ClusterSpec::mini();
        let policy = SimPolicy {
            locality_wait: 0.3,
            speculation: Some(SpecPolicy { quantile: 0.5, multiplier: 1.4 }),
        };
        let (s, ss, i, is) = drain_both(&c, policy, true, |sim| {
            sim.set_pool(2, PoolSpec { weight: 2.0, min_share: 1 });
            for j in 0..3usize {
                let tasks: Vec<TaskSpec> = (0..14)
                    .map(|k| {
                        TaskSpec::new(vec![
                            Phase::Cpu { secs: 0.1 + (k % 5) as f64 * 0.04 },
                            Phase::DiskRead { bytes: 2e6 * (1 + k % 3) as f64 },
                            Phase::NetIn { bytes: 1e6 },
                            Phase::DiskWrite { bytes: 1.5e6 },
                        ])
                        .on((k % 4) as NodeId)
                    })
                    .collect();
                sim.submit(
                    j,
                    &tasks,
                    &SimOpts {
                        jitter: 0.06,
                        seed: 100 + j as u64,
                        straggler: Some(super::super::Straggler { prob: 0.25, factor: 7.0 }),
                    },
                );
            }
        });
        assert_streams_identical(&s, &i);
        // Same events, same work — different discovery costs.
        assert_eq!(ss.events, is.events);
        assert_eq!(ss.task_launches, is.task_launches);
        assert_eq!(ss.flow_rolls, is.flow_rolls);
        assert_eq!(ss.heap_ops(), 0, "scan mode must not touch the heap");
        assert!(is.heap_ops() > 0, "indexed mode must use the heap");
    }

    #[test]
    fn indexed_core_saves_scan_work() {
        // A disk-heavy many-wave stage: most events touch one node's
        // flows, so the dirty rule must roll far fewer flows than a
        // per-event rescan of every live copy would.
        let c = ClusterSpec::mini();
        let mut sim = EventSim::new(&c, Box::new(FifoScheduler));
        let tasks: Vec<TaskSpec> = (0..64)
            .map(|k| {
                TaskSpec::new(vec![
                    Phase::Cpu { secs: 0.02 + (k % 7) as f64 * 0.01 },
                    Phase::DiskWrite { bytes: 4e6 },
                ])
            })
            .collect();
        sim.submit(0, &tasks, &SimOpts { jitter: 0.05, seed: 3, straggler: None });
        sim.drain();
        let st = sim.stats();
        assert!(st.events > 0);
        assert!(
            st.flow_rolls < st.live_copy_event_sum,
            "dirty-resource rolls ({}) must undercut events × running ({})",
            st.flow_rolls,
            st.live_copy_event_sum
        );
        assert!(st.scan_work_saved() > 0);
    }

    #[test]
    fn shaped_submission_matches_taskspec_submission() {
        // The engine's fast path (shared template + one preferred node
        // per task) must reproduce the generic TaskSpec path bit for bit,
        // jitter, stragglers and speculation included.
        let c = ClusterSpec::mini();
        let policy = SimPolicy {
            locality_wait: 0.2,
            speculation: Some(SpecPolicy { quantile: 0.5, multiplier: 1.5 }),
        };
        let template = [
            Phase::Fixed { secs: 0.01 },
            Phase::NetIn { bytes: 1e6 },
            Phase::Cpu { secs: 0.15 },
            Phase::DiskWrite { bytes: 2e6 },
        ];
        let prefs: Vec<NodeId> = (0..20).map(|k| (k % 4) as NodeId).collect();
        let opts = SimOpts {
            jitter: 0.07,
            seed: 0xAB,
            straggler: Some(super::super::Straggler { prob: 0.3, factor: 5.0 }),
        };
        let via_tasks = {
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            let tasks: Vec<TaskSpec> = prefs
                .iter()
                .map(|&n| TaskSpec::new(template.to_vec()).on(n))
                .collect();
            sim.submit(0, &tasks, &opts);
            sim.drain()
        };
        let via_shape = {
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            sim.submit_shaped(
                0,
                &StageSpec {
                    template: &template,
                    preferred: &prefs,
                    pref_width: 1,
                    tasks: prefs.len(),
                },
                &opts,
            );
            sim.drain()
        };
        assert_streams_identical(&via_tasks, &via_shape);
        // And without preferences.
        let a = {
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            sim.submit(0, &cpu_tasks(9, 0.3), &opts);
            sim.drain()
        };
        let b = {
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            sim.submit_shaped(
                0,
                &StageSpec {
                    template: &[Phase::Cpu { secs: 0.3 }],
                    preferred: &[],
                    pref_width: 1,
                    tasks: 9,
                },
                &opts,
            );
            sim.drain()
        };
        assert_streams_identical(&a, &b);
    }

    #[test]
    fn shaped_replica_lists_match_on_any_of_taskspecs() {
        // The replicated-block fast path: a width-2 preference table
        // must reproduce per-task `on_any_of` specs bit for bit, with
        // delay scheduling in play so preference *order* matters.
        let c = ClusterSpec::mini();
        let policy = SimPolicy { locality_wait: 0.25, speculation: None };
        let template =
            [Phase::Cpu { secs: 0.12 }, Phase::DiskRead { bytes: 2e6 }, Phase::Cpu { secs: 0.05 }];
        let w = 2usize;
        let tasks = 18usize;
        let prefs: Vec<NodeId> =
            (0..tasks * w).map(|k| ((k * 3 + k / w) % 4) as NodeId).collect();
        let opts = SimOpts { jitter: 0.06, seed: 0xCE, straggler: None };
        let via_tasks = {
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            let specs: Vec<TaskSpec> = (0..tasks)
                .map(|t| TaskSpec::new(template.to_vec()).on_any_of(&prefs[t * w..(t + 1) * w]))
                .collect();
            sim.submit(0, &specs, &opts);
            sim.drain()
        };
        let via_shape = {
            let mut sim = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
            sim.submit_shaped(
                0,
                &StageSpec { template: &template, preferred: &prefs, pref_width: w, tasks },
                &opts,
            );
            sim.drain()
        };
        assert_streams_identical(&via_tasks, &via_shape);
        // The Scan core re-checks every admission pick against the
        // linear reference; run the shaped variant through it too.
        let via_scan = {
            let mut sim = EventSim::with_discovery(
                &c,
                Box::new(FifoScheduler),
                policy,
                Discovery::Scan,
            );
            sim.submit_shaped(
                0,
                &StageSpec { template: &template, preferred: &prefs, pref_width: w, tasks },
                &opts,
            );
            sim.drain()
        };
        assert_streams_identical(&via_shape, &via_scan);
    }

    #[test]
    fn time_heap_batch_pop_takes_the_whole_tie_group() {
        let mut h = TimeHeap::new();
        for id in [9u32, 4, 6, 1, 12] {
            h.set(id, 2.0);
        }
        h.set(3, 2.5);
        h.set(8, 5.0);
        let mut out = Vec::new();
        assert_eq!(h.pop_due_into(2.0, &mut out), 5);
        out.sort_unstable();
        assert_eq!(out, vec![1, 4, 6, 9, 12], "the whole tie group pops in one pass");
        assert_eq!(h.peek(), Some((2.5, 3)), "survivors keep heap order");
        assert_eq!(h.len(), 2);
        // Popped ids are re-insertable (position table fully cleared).
        assert!(h.set(4, 1.0));
        assert_eq!(h.pop(), Some((1.0, 4)));
        // Nothing due → no-op.
        let mut none = Vec::new();
        assert_eq!(h.pop_due_into(0.5, &mut none), 0);
        assert!(none.is_empty());
    }

    #[test]
    fn time_heap_batch_pop_matches_sequential_pops() {
        // Randomized: dense keys force large tie groups; the batch pop
        // must return exactly the sequential pops' set and leave the
        // heap draining in the identical total order.
        let mut rng = Prng::new(0x7E57_AB);
        for case in 0..300 {
            let mut batched = TimeHeap::new();
            let mut reference = TimeHeap::new();
            let n = 1 + (case % 48) as u32;
            for id in 0..n {
                let key = rng.below(12) as f64 * 0.5;
                batched.set(id, key);
                reference.set(id, key);
            }
            let cutoff = rng.below(12) as f64 * 0.5;
            let mut batch = Vec::new();
            batched.pop_due_into(cutoff, &mut batch);
            batch.sort_unstable();
            let mut seq = Vec::new();
            while let Some((k, id)) = reference.peek() {
                if k > cutoff {
                    break;
                }
                reference.pop();
                seq.push(id);
            }
            seq.sort_unstable();
            assert_eq!(batch, seq, "case {case}: due sets diverged");
            let rest_a: Vec<(u64, u32)> =
                std::iter::from_fn(|| batched.pop().map(|(k, i)| (k.to_bits(), i))).collect();
            let rest_b: Vec<(u64, u32)> =
                std::iter::from_fn(|| reference.pop().map(|(k, i)| (k.to_bits(), i))).collect();
            assert_eq!(rest_a, rest_b, "case {case}: survivors diverged");
        }
    }

    #[test]
    fn bucketed_admission_probes_buckets_not_the_pending_queue() {
        // Node 0's cores are pinned busy; a 1000-task stage holds for
        // node 0 under a long locality wait while a third job churns
        // the remaining cores. Every admission offer used to scan all
        // held tasks (O(pending)); the bucketed path probes only the
        // free nodes' — empty — buckets, so the total probe count stays
        // below even ONE linear scan of the held queue.
        let mut c = quiet();
        c.nodes = 4;
        c.cores_per_node = 2;
        let held_tasks = 1000usize;
        let mut sim = EventSim::with_policy(
            &c,
            Box::new(FifoScheduler),
            SimPolicy { locality_wait: 1e6, speculation: None },
        );
        sim.submit(
            0,
            &[
                TaskSpec::new(vec![Phase::Cpu { secs: 1000.0 }]).on(0),
                TaskSpec::new(vec![Phase::Cpu { secs: 1000.0 }]).on(0),
            ],
            &opts0(),
        );
        let held: Vec<TaskSpec> =
            (0..held_tasks).map(|_| TaskSpec::new(vec![Phase::Cpu { secs: 1.0 }]).on(0)).collect();
        sim.submit(1, &held, &opts0());
        sim.submit(2, &cpu_tasks(60, 0.5), &opts0());
        // Run until the churn job completes; the held stage was offered
        // (and skipped) at every one of those admission passes.
        loop {
            let done = sim.advance().expect("churn job completes");
            if done.job == 2 {
                break;
            }
        }
        let st = sim.stats();
        assert!(st.admit_probes > 0, "bucket probes are counted");
        assert!(
            st.admit_probes < held_tasks as u64,
            "{} probes for the whole churn — a single linear offer of the held stage \
             would already cost {held_tasks}",
            st.admit_probes
        );
    }

    #[test]
    fn checkpoint_resume_reproduces_the_stream_bitwise() {
        // Snapshot mid-run — holds pending, speculation armed, flows in
        // flight — then finish twice: once on the original core, once on
        // a resumed clone (including a post-checkpoint submission). The
        // two tails must match bit for bit and the resumed stats must
        // agree under the logical projection while exposing the saved
        // work through `replayed_events`/`forked_trials`.
        let c = ClusterSpec::mini();
        let policy = SimPolicy {
            locality_wait: 0.2,
            speculation: Some(SpecPolicy { quantile: 0.5, multiplier: 1.4 }),
        };
        let opts = |j: u64| SimOpts {
            jitter: 0.05,
            seed: 21 + j,
            straggler: Some(super::super::Straggler { prob: 0.25, factor: 6.0 }),
        };
        let mixed = |n: usize| -> Vec<TaskSpec> {
            (0..n)
                .map(|k| {
                    TaskSpec::new(vec![
                        Phase::Cpu { secs: 0.1 + (k % 5) as f64 * 0.04 },
                        Phase::DiskWrite { bytes: 2e6 * (1 + k % 3) as f64 },
                        Phase::NetIn { bytes: 1e6 },
                    ])
                    .on((k % 4) as NodeId)
                })
                .collect()
        };
        let mut full = EventSim::with_policy(&c, Box::new(FifoScheduler), policy);
        full.set_pool(1, PoolSpec { weight: 2.0, min_share: 1 });
        full.submit(0, &mixed(14), &opts(0));
        full.submit(1, &mixed(10), &opts(1));
        let first = full.advance().expect("two stages in flight");
        let cp = full.checkpoint();
        assert!(cp.events() > 0);
        assert!(cp.at() > 0.0);
        assert_eq!(cp.open_stages(), 1);

        let finish = |sim: &mut EventSim<'_>| {
            sim.submit(0, &mixed(6), &opts(2));
            sim.drain()
        };
        let full_tail = finish(&mut full);
        let mut resumed = EventSim::resume(&c, Box::new(FifoScheduler), &cp);
        let resumed_tail = finish(&mut resumed);
        assert_streams_identical(&full_tail, &resumed_tail);
        let (fs, rs) = (full.stats(), resumed.stats());
        assert_eq!(fs.logical(), rs.logical(), "whole-timeline counters must agree");
        assert_eq!(fs.forked_trials, 0);
        assert_eq!(fs.replayed_events, 0);
        assert_eq!(rs.forked_trials, 1);
        assert_eq!(rs.replayed_events, cp.events());
        assert!(rs.processed_events() < fs.events, "the resumed run skipped the prefix");
        let _ = first;
    }
}
