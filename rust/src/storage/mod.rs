//! Block-manager / RDD-cache model (`spark.storage.memoryFraction`,
//! `spark.rdd.compress`).
//!
//! Spark 1.5 MEMORY_ONLY semantics: when an RDD is persisted, each
//! computed partition is *unrolled* into the storage pool; partitions
//! that don't fit are **dropped, not spilled** (blocks of an RDD never
//! evict sibling blocks), and every later access recomputes them from
//! lineage — and re-attempts the cache, churning allocations. So the
//! cached fraction is simply `pool / dataset` (capped at 1) and the miss
//! path costs recomputation every iteration — the mechanism behind the
//! paper's k-means case study (654 s → 54 s by raising
//! `storage.memoryFraction` from 0.6 to 0.7 so the points RDD fits).
//!
//! With `spark.rdd.compress=true` **and a serialized persistence level**
//! (MEMORY_ONLY_SER), the cached form is serialized-then-compressed:
//! ~2–4× more partitions fit, at decompress+deserialize CPU on *every*
//! access — the CPU-vs-memory trade-off of Sec. 3 (7). With the plain
//! MEMORY_ONLY level that all of the paper's benchmarks use,
//! `rdd.compress` is a **no-op** (true Spark 1.5 semantics: the flag only
//! governs serialized blocks) — which is exactly why Figs 1–3 show it
//! within noise.

use crate::codec::CodecProfile;
use crate::conf::SparkConf;

/// RDD persistence level (subset the benchmarks use).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PersistLevel {
    /// Deserialized objects in the storage pool (the benchmarks' level).
    MemoryOnly,
    /// Serialized (+ compressed when `spark.rdd.compress=true`) blocks.
    MemoryOnlySer,
}
use crate::exec::CACHE_DESER_FACTOR;
use crate::ser::SerProfile;
use crate::shuffle::IoProfiles;

/// Memory-bandwidth-class scan rate for cached deserialized partitions,
/// bytes/s per core (object graph traversal, not memcpy).
pub const CACHE_SCAN_BW: f64 = 4.0e9;

/// How a persisted dataset fits in the cluster-wide storage pool.
#[derive(Clone, Debug)]
pub struct CachePlan {
    /// Fraction of partitions that fit (Spark drops the rest).
    pub cached_fraction: f64,
    /// Bytes resident in the storage pool, cluster-wide.
    pub stored_bytes: u64,
    /// Stored form is serialized(+compressed)?
    pub serialized: bool,
}

/// Size the cache for a dataset of `payload` bytes / `records` records.
///
/// `pool_total` is the cluster-wide storage pool
/// (nodes × heap × storage.memoryFraction × safety).
pub fn plan_cache(
    conf: &SparkConf,
    prof: &IoProfiles,
    level: PersistLevel,
    pool_total: u64,
    payload: u64,
    records: u64,
    entropy: f64,
) -> CachePlan {
    let (stored_form_bytes, serialized) = if level == PersistLevel::MemoryOnlySer {
        let wire = prof.ser.wire_bytes(payload, records) as f64;
        let f = if conf.rdd_compress { prof.codec.compressed_fraction(entropy) } else { 1.0 };
        (wire * f, true)
    } else {
        (payload as f64 * CACHE_DESER_FACTOR, false)
    };
    let cached_fraction = (pool_total as f64 / stored_form_bytes).min(1.0);
    CachePlan {
        cached_fraction,
        stored_bytes: (stored_form_bytes * cached_fraction) as u64,
        serialized,
    }
}

/// CPU seconds for one task to materialize `payload` bytes / `records`
/// records from cache (scan, plus decompress+deserialize if stored
/// serialized).
pub fn cache_read_cpu(
    conf: &SparkConf,
    ser: &SerProfile,
    codec: &CodecProfile,
    level: PersistLevel,
    payload: u64,
    records: u64,
    entropy: f64,
) -> f64 {
    if level == PersistLevel::MemoryOnlySer {
        let mut t = ser.deserialize_secs(payload, records);
        if conf.rdd_compress {
            let wire = ser.wire_bytes(payload, records);
            t += codec
                .decompress_secs((wire as f64 * codec.compressed_fraction(entropy)) as u64);
        }
        t
    } else {
        payload as f64 / CACHE_SCAN_BW
    }
}

/// CPU seconds for one task to store `payload`/`records` into the cache.
pub fn cache_write_cpu(
    conf: &SparkConf,
    ser: &SerProfile,
    codec: &CodecProfile,
    level: PersistLevel,
    payload: u64,
    records: u64,
) -> f64 {
    if level == PersistLevel::MemoryOnlySer {
        let mut t = ser.serialize_secs(payload, records);
        if conf.rdd_compress {
            t += codec.compress_secs(ser.wire_bytes(payload, records));
        }
        t
    } else {
        // Unroll bookkeeping only.
        payload as f64 / (4.0 * CACHE_SCAN_BW)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::exec::MemoryModel;

    fn pool(conf: &SparkConf) -> u64 {
        let cluster = ClusterSpec::marenostrum();
        MemoryModel::new(conf, &cluster).storage_pool * cluster.nodes as u64
    }

    #[test]
    fn small_dataset_fully_cached() {
        let conf = SparkConf::default();
        let prof = IoProfiles::from_conf(&conf);
        // Fig-3 k-means: 100 M × 100 dims × 4 B = 40 GB, ×1.5 deser = 60 GB
        // against a 259 GB pool.
        let plan =
            plan_cache(&conf, &prof, PersistLevel::MemoryOnly, pool(&conf), 40 << 30, 100_000_000, 0.9);
        assert_eq!(plan.cached_fraction, 1.0);
        assert!(!plan.serialized);
        assert_eq!(plan.stored_bytes, (40u64 << 30) as u64 * 15 / 10);
    }

    #[test]
    fn case_study_dataset_straddles_fractions() {
        // 100 M × 500 dims × 4 B = 200 GB payload → 280 GB deserialized.
        // 0.6 pool = 259 GB → partial; 0.7 pool = 302 GB → full. This is
        // the paper's case-study-2 cliff.
        let payload = 200u64 << 30;
        let at06 = SparkConf::default();
        let prof = IoProfiles::from_conf(&at06);
        let p06 =
            plan_cache(&at06, &prof, PersistLevel::MemoryOnly, pool(&at06), payload, 100_000_000, 0.9);
        assert!(p06.cached_fraction < 0.95, "{}", p06.cached_fraction);
        let at07 = SparkConf::default()
            .with("spark.storage.memoryFraction", "0.7")
            .with("spark.shuffle.memoryFraction", "0.1");
        let p07 =
            plan_cache(&at07, &prof, PersistLevel::MemoryOnly, pool(&at07), payload, 100_000_000, 0.9);
        assert_eq!(p07.cached_fraction, 1.0);
    }

    #[test]
    fn rdd_compress_is_noop_for_memory_only() {
        // Spark 1.5 semantics: the flag only affects serialized levels.
        let plain = SparkConf::default();
        let flagged = plain.clone().with("spark.rdd.compress", "true");
        let prof = IoProfiles::from_conf(&plain);
        let a = plan_cache(&plain, &prof, PersistLevel::MemoryOnly, 1 << 40, 1 << 30, 1 << 20, 0.5);
        let b =
            plan_cache(&flagged, &prof, PersistLevel::MemoryOnly, 1 << 40, 1 << 30, 1 << 20, 0.5);
        assert_eq!(a.stored_bytes, b.stored_bytes);
        assert!(!b.serialized);
        let ra = cache_read_cpu(&plain, &prof.ser, &prof.codec, PersistLevel::MemoryOnly, 1 << 30, 1 << 20, 0.5);
        let rb = cache_read_cpu(&flagged, &prof.ser, &prof.codec, PersistLevel::MemoryOnly, 1 << 30, 1 << 20, 0.5);
        assert_eq!(ra, rb);
    }

    #[test]
    fn rdd_compress_fits_more_but_costs_cpu_when_serialized() {
        let plain = SparkConf::default();
        let compressed = plain.clone().with("spark.rdd.compress", "true");
        let prof = IoProfiles::from_conf(&plain);
        let payload = 400u64 << 30; // too big deserialized
        let lvl = PersistLevel::MemoryOnlySer;
        let p_ser = plan_cache(&plain, &prof, lvl, pool(&plain), payload, 1 << 30, 0.5);
        let p_comp = plan_cache(&compressed, &prof, lvl, pool(&compressed), payload, 1 << 30, 0.5);
        assert!(p_comp.cached_fraction > p_ser.cached_fraction);
        assert!(p_comp.serialized);
        let r_plain =
            cache_read_cpu(&plain, &prof.ser, &prof.codec, PersistLevel::MemoryOnly, 1 << 30, 1 << 20, 0.5);
        let r_comp =
            cache_read_cpu(&compressed, &prof.ser, &prof.codec, lvl, 1 << 30, 1 << 20, 0.5);
        assert!(r_comp > r_plain * 2.0, "compressed read {r_comp} vs plain {r_plain}");
    }

    #[test]
    fn cache_write_costs_are_modest_when_plain() {
        let conf = SparkConf::default();
        let prof = IoProfiles::from_conf(&conf);
        let lvl = PersistLevel::MemoryOnly;
        let w = cache_write_cpu(&conf, &prof.ser, &prof.codec, lvl, 1 << 30, 1 << 20);
        let r = cache_read_cpu(&conf, &prof.ser, &prof.codec, lvl, 1 << 30, 1 << 20, 0.5);
        assert!(w < r, "unroll write {w} should be cheaper than scan read {r}");
    }
}
