//! The tuning-service stress scenarios: M tenants × N apps against a
//! shared (possibly sharded) tuning service, cold then fully warm —
//! plus a saturation mode with 1k+ sessions, windowed admission
//! control, and per-tenant fairness caps.
//!
//! Every tenant tunes the same small app catalog (overlapping
//! workloads are exactly what a shared tuning service sees in
//! production), so identical trials across tenants dedupe through the
//! memo cache and the single-flight table: the simulated-trial count
//! must come out strictly below the requested-trial count. A second,
//! fully-warm pass re-serves the identical batch — every trial hits the
//! cache — and the outcomes must stay bit-identical to the cold pass,
//! which [`StressReport::deterministic`] checks and the CLI `serve`
//! subcommand (CI smoke) enforces. Batches are served through a
//! [`ShardedRouter`] ([`StressOpts::service_shards`], default 1), which
//! is pinned bit-identical to a plain
//! [`TuningService`](crate::service::TuningService) — so every
//! assertion above holds at any shard count.
//!
//! [`service_saturation`] is the scaling scenario behind
//! `BENCH_service.json`: a deterministic stream of
//! [`SaturationOpts::sessions`] sessions with a deliberately hot tenant
//! is admitted in fixed-size windows, at most
//! [`SaturationOpts::tenant_cap`] sessions per tenant per window
//! (excess defers, in order, to the next window), and each window is
//! served across the router's shards.

use crate::cluster::ClusterSpec;
use crate::conf::SparkConf;
use crate::engine::Job;
use crate::report::Table;
use crate::service::{
    outcomes_identical, ServiceOpts, ServiceStats, SessionOutcome, SessionRequest, ShardedRouter,
};
use crate::sim::SimOpts;
use crate::tuner::TuneOpts;
use crate::workloads;
use std::collections::{HashMap, VecDeque};

/// Stress-scenario sizing.
#[derive(Clone, Copy, Debug)]
pub struct StressOpts {
    /// Concurrent tenants (each runs the whole app catalog).
    pub tenants: u32,
    /// Apps per tenant (cycling through the catalog).
    pub apps: u32,
    /// Service worker threads.
    pub workers: usize,
    /// Memo-cache capacity in trials.
    pub capacity: usize,
    /// Memo-cache lock stripes.
    pub shards: usize,
    /// Enable cross-workload evidence transfer: the second pass's
    /// sessions warm-start from the first pass's recorded evidence
    /// (identical workloads → distance-0 neighbors), so the rerun runs
    /// strictly fewer trials instead of being bit-identical.
    pub warm_start: bool,
    /// Router shards ([`ShardedRouter`]) the batch is partitioned over
    /// by profile hash. 1 (the default) is the single-service layout;
    /// any N is pinned bit-identical to it.
    pub service_shards: usize,
}

impl Default for StressOpts {
    fn default() -> Self {
        StressOpts {
            tenants: 4,
            apps: 3,
            workers: 4,
            capacity: 4096,
            shards: 8,
            warm_start: false,
            service_shards: 1,
        }
    }
}

/// Small-scale app catalog entry `a`: shuffle-heavy, CPU/cache-heavy and
/// combine-heavy apps alternate; sizes grow every full cycle so distinct
/// apps stay distinct trials.
fn catalog(a: u32) -> Job {
    let scale = 1 + a as u64 / 3;
    match a % 3 {
        0 => workloads::sort_by_key(2_000_000 * scale, 16),
        1 => workloads::kmeans(100_000 * scale, 20, 4, 2, 16),
        _ => workloads::aggregate_by_key(2_000_000 * scale, 50_000, 16),
    }
}

/// Build the M×N session batch. Tenants share apps *and* seeds — tenant
/// `t`'s app `a` is the same trial stream as every other tenant's app
/// `a`, so the overlap is maximal by construction.
pub fn stress_requests(tenants: u32, apps: u32) -> Vec<SessionRequest> {
    stress_requests_with_base(tenants, apps, &SparkConf::default())
}

/// [`stress_requests`] with a non-default base configuration riding
/// under every session's trials (the CLI's `serve --conf k=v` path).
pub fn stress_requests_with_base(
    tenants: u32,
    apps: u32,
    base: &SparkConf,
) -> Vec<SessionRequest> {
    let mut reqs = Vec::with_capacity(tenants as usize * apps as usize);
    for t in 0..tenants {
        for a in 0..apps {
            reqs.push(SessionRequest {
                name: format!("tenant{t}/app{a}"),
                job: catalog(a),
                tune: TuneOpts { short_version: true, base: base.clone(), ..TuneOpts::default() },
                sim: SimOpts { jitter: 0.04, seed: 0x5E21E + a as u64, straggler: None },
            });
        }
    }
    reqs
}

/// Outcome of the stress scenario: the cold pass, the fully-warm rerun,
/// and counter snapshots after each.
#[derive(Clone, Debug)]
pub struct StressReport {
    pub opts: StressOpts,
    pub cold: Vec<SessionOutcome>,
    pub warm: Vec<SessionOutcome>,
    /// Counters after the cold pass only.
    pub cold_stats: ServiceStats,
    /// Cumulative counters after both passes.
    pub stats: ServiceStats,
    pub cold_wall_secs: f64,
    pub warm_wall_secs: f64,
}

impl StressReport {
    /// Bitwise parity between the cold pass and the warm rerun — the
    /// service's core correctness claim.
    pub fn deterministic(&self) -> bool {
        self.cold.len() == self.warm.len()
            && self
                .cold
                .iter()
                .zip(&self.warm)
                .all(|(c, w)| outcomes_identical(&c.outcome, &w.outcome))
    }

    /// Sessions per wall-clock second in the cold pass.
    pub fn cold_jobs_per_sec(&self) -> f64 {
        self.cold.len() as f64 / self.cold_wall_secs.max(1e-9)
    }

    /// Sessions per wall-clock second in the warm pass.
    pub fn warm_jobs_per_sec(&self) -> f64 {
        self.warm.len() as f64 / self.warm_wall_secs.max(1e-9)
    }

    /// Trials the second pass requested (cumulative minus cold-pass).
    pub fn pass2_requested(&self) -> u64 {
        self.stats.trials_requested.saturating_sub(self.cold_stats.trials_requested)
    }

    /// The warm-start mode's acceptance predicate: every second-pass
    /// session transferred (strictly fewer runs than its first-pass
    /// twin) and none ended with a worse final duration.
    pub fn transfer_won(&self) -> bool {
        self.cold.len() == self.warm.len()
            && self.cold.iter().zip(&self.warm).all(|(c, w)| {
                w.warm_from.is_some()
                    && w.outcome.runs() < c.outcome.runs()
                    && w.outcome.best <= c.outcome.best
            })
    }
}

/// Run the stress scenario: serve the batch cold, then re-serve it
/// fully warm on the same service.
pub fn service_stress(o: &StressOpts, cluster: &ClusterSpec) -> StressReport {
    service_stress_with_base(o, cluster, &SparkConf::default())
}

/// [`service_stress`] under a non-default base configuration
/// ([`StressOpts`] is `Copy`-sized on purpose, so the base rides
/// alongside rather than inside it).
/// The router a stress/saturation scenario serves through:
/// [`StressOpts::service_shards`] services, each sized by the
/// remaining knobs, with cross-shard evidence transfer when
/// [`StressOpts::warm_start`] is on.
pub fn stress_router(o: &StressOpts, cluster: &ClusterSpec) -> ShardedRouter {
    ShardedRouter::new(
        cluster.clone(),
        o.service_shards,
        ServiceOpts {
            workers: o.workers,
            shards: o.shards,
            capacity: o.capacity,
            warm_start: o.warm_start,
            ..ServiceOpts::default()
        },
    )
}

pub fn service_stress_with_base(
    o: &StressOpts,
    cluster: &ClusterSpec,
    base: &SparkConf,
) -> StressReport {
    let reqs = stress_requests_with_base(o.tenants, o.apps, base);
    let svc = stress_router(o, cluster);
    let t0 = std::time::Instant::now();
    let cold = svc.serve(&reqs);
    let cold_wall_secs = t0.elapsed().as_secs_f64();
    let cold_stats = svc.stats();
    let t1 = std::time::Instant::now();
    let warm = svc.serve(&reqs);
    let warm_wall_secs = t1.elapsed().as_secs_f64();
    StressReport {
        opts: *o,
        cold,
        warm,
        cold_stats,
        stats: svc.stats(),
        cold_wall_secs,
        warm_wall_secs,
    }
}

/// Render the service stats as a markdown/CSV table (the `serve` CLI
/// emits this; wall-clock rows vary run to run, counters don't).
pub fn service_table(r: &StressReport) -> Table {
    let s = &r.stats;
    let c = &r.cold_stats;
    Table::two_col(
        format!(
            "Tuning service — {} tenants × {} apps, {} workers",
            r.opts.tenants, r.opts.apps, r.opts.workers
        ),
        &[
            ("sessions served (cold + warm)", s.sessions.to_string()),
            ("trials requested", s.trials_requested.to_string()),
            ("trials simulated", s.trials_simulated.to_string()),
            (
                "cold-pass dedup (simulated / requested)",
                format!("{} / {}", c.trials_simulated, c.trials_requested),
            ),
            ("in-flight coalesced", s.coalesced.to_string()),
            ("service hit rate", format!("{:.1}%", 100.0 * s.hit_rate())),
            ("cache hit rate (raw lookups)", format!("{:.1}%", 100.0 * s.cache.hit_rate())),
            ("cache evictions", s.cache.evictions.to_string()),
            (
                "cold pass",
                format!("{:.3}s ({:.1} jobs/sec)", r.cold_wall_secs, r.cold_jobs_per_sec()),
            ),
            (
                "warm pass",
                format!("{:.3}s ({:.1} jobs/sec)", r.warm_wall_secs, r.warm_jobs_per_sec()),
            ),
            ("cold ≡ warm (bit-identical)", r.deterministic().to_string()),
        ],
    )
}

/// Saturation-scenario sizing. Defaults model a busy shared service:
/// 1k+ sessions, a deliberately hot tenant, fixed admission windows,
/// and a 4-shard router.
#[derive(Clone, Copy, Debug)]
pub struct SaturationOpts {
    /// Total sessions in the stream.
    pub sessions: usize,
    /// Tenants the stream is spread over; tenant 0 is **hot** (every
    /// 4th session is its, on top of its round-robin share), so the
    /// fairness cap visibly defers it.
    pub tenants: u32,
    /// Distinct catalog apps cycled through the stream.
    pub apps: u32,
    /// Sessions admitted per window (min 1).
    pub window: usize,
    /// Max sessions one tenant may occupy in a single window (min 1);
    /// the excess defers, in arrival order, to later windows.
    pub tenant_cap: usize,
    /// Router shards.
    pub service_shards: usize,
    /// Worker threads per shard.
    pub workers: usize,
    /// Memo-cache capacity per shard, in trials.
    pub capacity: usize,
    /// Memo-cache lock stripes per shard.
    pub cache_shards: usize,
    /// Cross-shard evidence transfer (on by default: a saturated
    /// service is exactly where transfer pays).
    pub warm_start: bool,
}

impl Default for SaturationOpts {
    fn default() -> Self {
        SaturationOpts {
            sessions: 1024,
            tenants: 8,
            apps: 12,
            window: 64,
            tenant_cap: 4,
            service_shards: 4,
            workers: 4,
            capacity: 4096,
            cache_shards: 8,
            warm_start: true,
        }
    }
}

/// Mini-scale catalog for the saturation stream: the same three
/// workload families as [`catalog`], small enough that a 1k-session
/// stream stays a smoke-sized run (distinct apps still price distinct
/// trials; repeated ones memoize).
fn mini_catalog(a: u32) -> Job {
    let scale = 1 + a as u64 / 3;
    match a % 3 {
        0 => workloads::sort_by_key(250_000 * scale, 8),
        1 => workloads::kmeans(20_000 * scale, 10, 4, 2, 8),
        _ => workloads::aggregate_by_key(250_000 * scale, 10_000, 8),
    }
}

/// The deterministic saturation stream: session `s` belongs to tenant
/// 0 when `s % 4 == 0` (the hot tenant) and round-robins otherwise,
/// and cycles the mini catalog. Returns `(tenant, request)` pairs in
/// arrival order.
pub fn saturation_requests(o: &SaturationOpts) -> Vec<(u32, SessionRequest)> {
    let tenants = o.tenants.max(1);
    let apps = o.apps.max(1);
    (0..o.sessions)
        .map(|s| {
            let tenant = if s % 4 == 0 { 0 } else { s as u32 % tenants };
            let app = s as u32 % apps;
            let req = SessionRequest {
                name: format!("tenant{tenant}/app{app}/s{s}"),
                job: mini_catalog(app),
                tune: TuneOpts { short_version: true, ..TuneOpts::default() },
                sim: SimOpts { jitter: 0.04, seed: 0x5A7 + app as u64, straggler: None },
            };
            (tenant, req)
        })
        .collect()
}

/// Outcome of the saturation scenario.
#[derive(Clone, Debug)]
pub struct SaturationReport {
    pub opts: SaturationOpts,
    /// Every session's outcome, in admission (served) order.
    pub outcomes: Vec<SessionOutcome>,
    /// Admission windows it took to drain the stream.
    pub windows: u64,
    /// Sessions pushed past their arrival window by the fairness cap.
    pub deferrals: u64,
    /// Largest per-tenant admission count observed in any single
    /// window — ≤ `tenant_cap` by construction (the fairness claim).
    pub max_tenant_window: usize,
    /// Aggregated router counters after the full stream.
    pub stats: ServiceStats,
    pub wall_secs: f64,
}

impl SaturationReport {
    /// Sessions per wall-clock second across the whole stream.
    pub fn jobs_per_sec(&self) -> f64 {
        self.outcomes.len() as f64 / self.wall_secs.max(1e-9)
    }
}

/// Run the saturation scenario: admit the stream in windows under the
/// per-tenant cap, serving each window across the router's shards.
/// Deterministic end to end — the stream, the admission schedule, and
/// (by the router's contract) every outcome.
pub fn service_saturation(o: &SaturationOpts, cluster: &ClusterSpec) -> SaturationReport {
    let window = o.window.max(1);
    let tenant_cap = o.tenant_cap.max(1);
    let router = stress_router(
        &StressOpts {
            tenants: o.tenants,
            apps: o.apps,
            workers: o.workers,
            capacity: o.capacity,
            shards: o.cache_shards,
            warm_start: o.warm_start,
            service_shards: o.service_shards,
        },
        cluster,
    );
    let mut pending: VecDeque<(u32, SessionRequest)> = saturation_requests(o).into();
    let mut outcomes = Vec::with_capacity(o.sessions);
    let mut windows = 0u64;
    let mut deferrals = 0u64;
    let mut max_tenant_window = 0usize;
    let t0 = std::time::Instant::now();
    while !pending.is_empty() {
        windows += 1;
        let mut admitted: Vec<SessionRequest> = Vec::with_capacity(window);
        let mut deferred: VecDeque<(u32, SessionRequest)> = VecDeque::new();
        let mut per_tenant: HashMap<u32, usize> = HashMap::new();
        while admitted.len() < window {
            let Some((tenant, req)) = pending.pop_front() else { break };
            let count = per_tenant.entry(tenant).or_insert(0);
            if *count < tenant_cap {
                *count += 1;
                max_tenant_window = max_tenant_window.max(*count);
                admitted.push(req);
            } else {
                deferrals += 1;
                deferred.push_back((tenant, req));
            }
        }
        // Deferred sessions keep their arrival order at the head of
        // the queue: the cap delays them, it never reorders them.
        while let Some(item) = deferred.pop_back() {
            pending.push_front(item);
        }
        let base = outcomes.len();
        for (i, mut out) in router.serve(&admitted).into_iter().enumerate() {
            out.session = base + i;
            outcomes.push(out);
        }
    }
    SaturationReport {
        opts: *o,
        outcomes,
        windows,
        deferrals,
        max_tenant_window,
        stats: router.stats(),
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

/// Render the saturation counters (the `serve --saturation` CLI emits
/// this; wall-clock rows vary run to run, counters don't).
pub fn saturation_table(r: &SaturationReport) -> Table {
    let s = &r.stats;
    Table::two_col(
        format!(
            "Service saturation — {} sessions, {} tenants, {}-shard router",
            r.outcomes.len(),
            r.opts.tenants,
            r.opts.service_shards
        ),
        &[
            ("sessions served", r.outcomes.len().to_string()),
            ("admission windows", r.windows.to_string()),
            ("fairness deferrals", r.deferrals.to_string()),
            (
                "max tenant share of a window",
                format!("{} (cap {})", r.max_tenant_window, r.opts.tenant_cap),
            ),
            ("trials requested", s.trials_requested.to_string()),
            ("trials simulated", s.trials_simulated.to_string()),
            ("service hit rate", format!("{:.1}%", 100.0 * s.hit_rate())),
            ("warm-started sessions", s.warm_started.to_string()),
            ("quarantined trials", s.quarantined.to_string()),
            ("wall", format!("{:.3}s ({:.1} jobs/sec)", r.wall_secs, r.jobs_per_sec())),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stress_dedupes_and_stays_deterministic() {
        let o = StressOpts {
            tenants: 3,
            apps: 2,
            workers: 4,
            capacity: 1024,
            shards: 4,
            warm_start: false,
            service_shards: 1,
        };
        let r = service_stress(&o, &ClusterSpec::mini());
        assert_eq!(r.cold.len(), 6);
        assert!(r.deterministic(), "warm rerun must be bit-identical to the cold pass");
        // Overlapping tenants: strictly fewer simulations than requests
        // already in the COLD pass.
        assert!(
            r.cold_stats.trials_simulated < r.cold_stats.trials_requested,
            "{} simulated of {} requested",
            r.cold_stats.trials_simulated,
            r.cold_stats.trials_requested
        );
        // The warm pass simulates nothing new.
        assert_eq!(r.stats.trials_simulated, r.cold_stats.trials_simulated);
        assert!(r.stats.hit_rate() > 0.0);
        // Two sessions of the same app across tenants agree exactly.
        assert!(outcomes_identical(&r.cold[0].outcome, &r.cold[2].outcome));
    }

    #[test]
    fn warm_start_mode_transfers_on_the_second_pass() {
        // With evidence transfer on, the rerun is *not* bit-identical —
        // it is strictly cheaper: every second-pass session warm-starts
        // from its first-pass twin (distance-0 neighbor), replays only
        // the kept steps, and ends at the same final duration.
        let o = StressOpts {
            tenants: 2,
            apps: 2,
            workers: 4,
            capacity: 1024,
            shards: 4,
            warm_start: true,
            service_shards: 1,
        };
        let r = service_stress(&o, &ClusterSpec::mini());
        assert!(r.transfer_won(), "second pass must transfer: {:?}", r.stats);
        assert!(
            r.pass2_requested() < r.cold_stats.trials_requested,
            "warm-started rerun must request fewer trials: {} vs {}",
            r.pass2_requested(),
            r.cold_stats.trials_requested
        );
        for (c, w) in r.cold.iter().zip(&r.warm) {
            assert_eq!(
                w.outcome.best.to_bits(),
                c.outcome.best.to_bits(),
                "{}: identical workload must reach the identical final duration",
                w.name
            );
        }
        // First pass ran cold (nothing recorded at admission time).
        assert!(r.cold.iter().all(|c| c.warm_from.is_none()));
        assert_eq!(r.stats.warm_started, r.warm.len() as u64);
    }

    #[test]
    fn stress_is_reproducible_across_services() {
        // A fresh service (fresh cache, different thread interleavings)
        // reaches identical outcomes: purity end to end.
        let o = StressOpts {
            tenants: 2,
            apps: 2,
            workers: 3,
            capacity: 512,
            shards: 2,
            warm_start: false,
            service_shards: 1,
        };
        let a = service_stress(&o, &ClusterSpec::mini());
        let b = service_stress(&o, &ClusterSpec::mini());
        for (x, y) in a.cold.iter().zip(&b.cold) {
            assert!(outcomes_identical(&x.outcome, &y.outcome), "{} diverged", x.name);
        }
    }

    #[test]
    fn table_reports_the_headline_counters() {
        let o = StressOpts {
            tenants: 2,
            apps: 1,
            workers: 2,
            capacity: 256,
            shards: 2,
            warm_start: false,
            service_shards: 1,
        };
        let r = service_stress(&o, &ClusterSpec::mini());
        let md = service_table(&r).to_markdown();
        assert!(md.contains("trials requested"), "{md}");
        assert!(md.contains("trials simulated"), "{md}");
        assert!(md.contains("jobs/sec"), "{md}");
        assert!(md.contains("| cold ≡ warm (bit-identical) | true |"), "{md}");
    }

    #[test]
    fn sharded_stress_matches_the_single_service_layout() {
        // The same stress scenario through a 3-shard router: outcomes,
        // warm-start decisions, and the determinism predicate all agree
        // with the 1-shard layout bitwise.
        let single = StressOpts {
            tenants: 2,
            apps: 2,
            workers: 2,
            capacity: 512,
            shards: 2,
            warm_start: true,
            service_shards: 1,
        };
        let sharded = StressOpts { service_shards: 3, ..single };
        let a = service_stress(&single, &ClusterSpec::mini());
        let b = service_stress(&sharded, &ClusterSpec::mini());
        for (x, y) in a.cold.iter().zip(&b.cold).chain(a.warm.iter().zip(&b.warm)) {
            assert!(outcomes_identical(&x.outcome, &y.outcome), "{} diverged", x.name);
            assert_eq!(x.warm_from, y.warm_from, "{}", x.name);
        }
        assert!(b.transfer_won(), "transfer must win at any shard count");
        assert_eq!(a.stats.warm_started, b.stats.warm_started);
    }

    #[test]
    fn saturation_enforces_the_fairness_cap_and_stays_deterministic() {
        let o = SaturationOpts {
            sessions: 48,
            tenants: 4,
            apps: 6,
            window: 8,
            tenant_cap: 2,
            service_shards: 2,
            workers: 2,
            capacity: 1024,
            cache_shards: 4,
            warm_start: true,
        };
        let r = service_saturation(&o, &ClusterSpec::mini());
        assert_eq!(r.outcomes.len(), 48, "every session must eventually be served");
        assert!(r.max_tenant_window <= 2, "cap violated: {}", r.max_tenant_window);
        // The hot tenant (0) over-demands, so the cap must actually bite.
        assert!(r.deferrals > 0, "the hot tenant must be deferred at least once");
        assert!(r.windows >= (48 / 8) as u64, "windows cannot beat the admission rate");
        assert_eq!(r.stats.sessions, 48);
        // Deterministic end to end: a second run reproduces everything.
        let r2 = service_saturation(&o, &ClusterSpec::mini());
        assert_eq!(r.windows, r2.windows);
        assert_eq!(r.deferrals, r2.deferrals);
        for (x, y) in r.outcomes.iter().zip(&r2.outcomes) {
            assert_eq!(x.name, y.name, "admission order must be reproducible");
            assert!(outcomes_identical(&x.outcome, &y.outcome), "{} diverged", x.name);
        }
        let md = saturation_table(&r).to_markdown();
        assert!(md.contains("fairness deferrals"), "{md}");
        assert!(md.contains("admission windows"), "{md}");
    }
}
