//! The tuning-service stress scenario: M tenants × N apps against one
//! shared [`TuningService`], cold then fully warm.
//!
//! Every tenant tunes the same small app catalog (overlapping
//! workloads are exactly what a shared tuning service sees in
//! production), so identical trials across tenants dedupe through the
//! memo cache and the single-flight table: the simulated-trial count
//! must come out strictly below the requested-trial count. A second,
//! fully-warm pass re-serves the identical batch — every trial hits the
//! cache — and the outcomes must stay bit-identical to the cold pass,
//! which [`StressReport::deterministic`] checks and the CLI `serve`
//! subcommand (CI smoke) enforces.

use crate::cluster::ClusterSpec;
use crate::conf::SparkConf;
use crate::engine::Job;
use crate::report::Table;
use crate::service::{
    outcomes_identical, ServiceOpts, ServiceStats, SessionOutcome, SessionRequest, TuningService,
};
use crate::sim::SimOpts;
use crate::tuner::TuneOpts;
use crate::workloads;

/// Stress-scenario sizing.
#[derive(Clone, Copy, Debug)]
pub struct StressOpts {
    /// Concurrent tenants (each runs the whole app catalog).
    pub tenants: u32,
    /// Apps per tenant (cycling through the catalog).
    pub apps: u32,
    /// Service worker threads.
    pub workers: usize,
    /// Memo-cache capacity in trials.
    pub capacity: usize,
    /// Memo-cache lock stripes.
    pub shards: usize,
    /// Enable cross-workload evidence transfer: the second pass's
    /// sessions warm-start from the first pass's recorded evidence
    /// (identical workloads → distance-0 neighbors), so the rerun runs
    /// strictly fewer trials instead of being bit-identical.
    pub warm_start: bool,
}

impl Default for StressOpts {
    fn default() -> Self {
        StressOpts {
            tenants: 4,
            apps: 3,
            workers: 4,
            capacity: 4096,
            shards: 8,
            warm_start: false,
        }
    }
}

/// Small-scale app catalog entry `a`: shuffle-heavy, CPU/cache-heavy and
/// combine-heavy apps alternate; sizes grow every full cycle so distinct
/// apps stay distinct trials.
fn catalog(a: u32) -> Job {
    let scale = 1 + a as u64 / 3;
    match a % 3 {
        0 => workloads::sort_by_key(2_000_000 * scale, 16),
        1 => workloads::kmeans(100_000 * scale, 20, 4, 2, 16),
        _ => workloads::aggregate_by_key(2_000_000 * scale, 50_000, 16),
    }
}

/// Build the M×N session batch. Tenants share apps *and* seeds — tenant
/// `t`'s app `a` is the same trial stream as every other tenant's app
/// `a`, so the overlap is maximal by construction.
pub fn stress_requests(tenants: u32, apps: u32) -> Vec<SessionRequest> {
    stress_requests_with_base(tenants, apps, &SparkConf::default())
}

/// [`stress_requests`] with a non-default base configuration riding
/// under every session's trials (the CLI's `serve --conf k=v` path).
pub fn stress_requests_with_base(
    tenants: u32,
    apps: u32,
    base: &SparkConf,
) -> Vec<SessionRequest> {
    let mut reqs = Vec::with_capacity(tenants as usize * apps as usize);
    for t in 0..tenants {
        for a in 0..apps {
            reqs.push(SessionRequest {
                name: format!("tenant{t}/app{a}"),
                job: catalog(a),
                tune: TuneOpts { short_version: true, base: base.clone(), ..TuneOpts::default() },
                sim: SimOpts { jitter: 0.04, seed: 0x5E21E + a as u64, straggler: None },
            });
        }
    }
    reqs
}

/// Outcome of the stress scenario: the cold pass, the fully-warm rerun,
/// and counter snapshots after each.
#[derive(Clone, Debug)]
pub struct StressReport {
    pub opts: StressOpts,
    pub cold: Vec<SessionOutcome>,
    pub warm: Vec<SessionOutcome>,
    /// Counters after the cold pass only.
    pub cold_stats: ServiceStats,
    /// Cumulative counters after both passes.
    pub stats: ServiceStats,
    pub cold_wall_secs: f64,
    pub warm_wall_secs: f64,
}

impl StressReport {
    /// Bitwise parity between the cold pass and the warm rerun — the
    /// service's core correctness claim.
    pub fn deterministic(&self) -> bool {
        self.cold.len() == self.warm.len()
            && self
                .cold
                .iter()
                .zip(&self.warm)
                .all(|(c, w)| outcomes_identical(&c.outcome, &w.outcome))
    }

    /// Sessions per wall-clock second in the cold pass.
    pub fn cold_jobs_per_sec(&self) -> f64 {
        self.cold.len() as f64 / self.cold_wall_secs.max(1e-9)
    }

    /// Sessions per wall-clock second in the warm pass.
    pub fn warm_jobs_per_sec(&self) -> f64 {
        self.warm.len() as f64 / self.warm_wall_secs.max(1e-9)
    }

    /// Trials the second pass requested (cumulative minus cold-pass).
    pub fn pass2_requested(&self) -> u64 {
        self.stats.trials_requested.saturating_sub(self.cold_stats.trials_requested)
    }

    /// The warm-start mode's acceptance predicate: every second-pass
    /// session transferred (strictly fewer runs than its first-pass
    /// twin) and none ended with a worse final duration.
    pub fn transfer_won(&self) -> bool {
        self.cold.len() == self.warm.len()
            && self.cold.iter().zip(&self.warm).all(|(c, w)| {
                w.warm_from.is_some()
                    && w.outcome.runs() < c.outcome.runs()
                    && w.outcome.best <= c.outcome.best
            })
    }
}

/// Run the stress scenario: serve the batch cold, then re-serve it
/// fully warm on the same service.
pub fn service_stress(o: &StressOpts, cluster: &ClusterSpec) -> StressReport {
    service_stress_with_base(o, cluster, &SparkConf::default())
}

/// [`service_stress`] under a non-default base configuration
/// ([`StressOpts`] is `Copy`-sized on purpose, so the base rides
/// alongside rather than inside it).
pub fn service_stress_with_base(
    o: &StressOpts,
    cluster: &ClusterSpec,
    base: &SparkConf,
) -> StressReport {
    let reqs = stress_requests_with_base(o.tenants, o.apps, base);
    let svc = TuningService::new(
        cluster.clone(),
        ServiceOpts {
            workers: o.workers,
            shards: o.shards,
            capacity: o.capacity,
            warm_start: o.warm_start,
            ..ServiceOpts::default()
        },
    );
    let t0 = std::time::Instant::now();
    let cold = svc.serve(&reqs);
    let cold_wall_secs = t0.elapsed().as_secs_f64();
    let cold_stats = svc.stats();
    let t1 = std::time::Instant::now();
    let warm = svc.serve(&reqs);
    let warm_wall_secs = t1.elapsed().as_secs_f64();
    StressReport {
        opts: *o,
        cold,
        warm,
        cold_stats,
        stats: svc.stats(),
        cold_wall_secs,
        warm_wall_secs,
    }
}

/// Render the service stats as a markdown/CSV table (the `serve` CLI
/// emits this; wall-clock rows vary run to run, counters don't).
pub fn service_table(r: &StressReport) -> Table {
    let s = &r.stats;
    let c = &r.cold_stats;
    Table::two_col(
        format!(
            "Tuning service — {} tenants × {} apps, {} workers",
            r.opts.tenants, r.opts.apps, r.opts.workers
        ),
        &[
            ("sessions served (cold + warm)", s.sessions.to_string()),
            ("trials requested", s.trials_requested.to_string()),
            ("trials simulated", s.trials_simulated.to_string()),
            (
                "cold-pass dedup (simulated / requested)",
                format!("{} / {}", c.trials_simulated, c.trials_requested),
            ),
            ("in-flight coalesced", s.coalesced.to_string()),
            ("service hit rate", format!("{:.1}%", 100.0 * s.hit_rate())),
            ("cache hit rate (raw lookups)", format!("{:.1}%", 100.0 * s.cache.hit_rate())),
            ("cache evictions", s.cache.evictions.to_string()),
            (
                "cold pass",
                format!("{:.3}s ({:.1} jobs/sec)", r.cold_wall_secs, r.cold_jobs_per_sec()),
            ),
            (
                "warm pass",
                format!("{:.3}s ({:.1} jobs/sec)", r.warm_wall_secs, r.warm_jobs_per_sec()),
            ),
            ("cold ≡ warm (bit-identical)", r.deterministic().to_string()),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stress_dedupes_and_stays_deterministic() {
        let o = StressOpts { tenants: 3, apps: 2, workers: 4, capacity: 1024, shards: 4, warm_start: false };
        let r = service_stress(&o, &ClusterSpec::mini());
        assert_eq!(r.cold.len(), 6);
        assert!(r.deterministic(), "warm rerun must be bit-identical to the cold pass");
        // Overlapping tenants: strictly fewer simulations than requests
        // already in the COLD pass.
        assert!(
            r.cold_stats.trials_simulated < r.cold_stats.trials_requested,
            "{} simulated of {} requested",
            r.cold_stats.trials_simulated,
            r.cold_stats.trials_requested
        );
        // The warm pass simulates nothing new.
        assert_eq!(r.stats.trials_simulated, r.cold_stats.trials_simulated);
        assert!(r.stats.hit_rate() > 0.0);
        // Two sessions of the same app across tenants agree exactly.
        assert!(outcomes_identical(&r.cold[0].outcome, &r.cold[2].outcome));
    }

    #[test]
    fn warm_start_mode_transfers_on_the_second_pass() {
        // With evidence transfer on, the rerun is *not* bit-identical —
        // it is strictly cheaper: every second-pass session warm-starts
        // from its first-pass twin (distance-0 neighbor), replays only
        // the kept steps, and ends at the same final duration.
        let o = StressOpts {
            tenants: 2,
            apps: 2,
            workers: 4,
            capacity: 1024,
            shards: 4,
            warm_start: true,
        };
        let r = service_stress(&o, &ClusterSpec::mini());
        assert!(r.transfer_won(), "second pass must transfer: {:?}", r.stats);
        assert!(
            r.pass2_requested() < r.cold_stats.trials_requested,
            "warm-started rerun must request fewer trials: {} vs {}",
            r.pass2_requested(),
            r.cold_stats.trials_requested
        );
        for (c, w) in r.cold.iter().zip(&r.warm) {
            assert_eq!(
                w.outcome.best.to_bits(),
                c.outcome.best.to_bits(),
                "{}: identical workload must reach the identical final duration",
                w.name
            );
        }
        // First pass ran cold (nothing recorded at admission time).
        assert!(r.cold.iter().all(|c| c.warm_from.is_none()));
        assert_eq!(r.stats.warm_started, r.warm.len() as u64);
    }

    #[test]
    fn stress_is_reproducible_across_services() {
        // A fresh service (fresh cache, different thread interleavings)
        // reaches identical outcomes: purity end to end.
        let o = StressOpts { tenants: 2, apps: 2, workers: 3, capacity: 512, shards: 2, warm_start: false };
        let a = service_stress(&o, &ClusterSpec::mini());
        let b = service_stress(&o, &ClusterSpec::mini());
        for (x, y) in a.cold.iter().zip(&b.cold) {
            assert!(outcomes_identical(&x.outcome, &y.outcome), "{} diverged", x.name);
        }
    }

    #[test]
    fn table_reports_the_headline_counters() {
        let o = StressOpts { tenants: 2, apps: 1, workers: 2, capacity: 256, shards: 2, warm_start: false };
        let r = service_stress(&o, &ClusterSpec::mini());
        let md = service_table(&r).to_markdown();
        assert!(md.contains("trials requested"), "{md}");
        assert!(md.contains("trials simulated"), "{md}");
        assert!(md.contains("jobs/sec"), "{md}");
        assert!(md.contains("| cold ≡ warm (bit-identical) | true |"), "{md}");
    }
}
