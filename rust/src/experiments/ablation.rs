//! E8 — the ablation behind the paper's headline efficiency claim:
//! "at most ten configurations … even if each parameter took only two
//! values, exhaustively checking all combinations would result in 2⁹ =
//! 512 runs". We measure what the ≤10-run decision list actually gives
//! up against exhaustive grid search (216 value combinations) and random
//! search at matched budgets.

use crate::cluster::ClusterSpec;
use crate::report::Table;
use crate::tuner::baselines::{exhaustive, random_search};
use crate::tuner::{tune, TuneOpts};
use crate::workloads::Workload;

/// One row of the ablation.
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub workload: &'static str,
    pub method: &'static str,
    pub runs: usize,
    pub best: f64,
    pub improvement_pct: f64,
}

/// Run methodology / exhaustive / random-search over `workloads`.
/// Exhaustive is 216 simulated runs per workload — run in release mode.
pub fn ablation(workloads: &[Workload], cluster: &ClusterSpec) -> Vec<AblationRow> {
    let mut rows = Vec::new();
    for &w in workloads {
        let mut method_runner = super::cases::sim_runner(w, cluster);
        let m = tune(&mut method_runner, &TuneOpts::default());
        rows.push(AblationRow {
            workload: w.name(),
            method: "fig4-methodology",
            runs: m.runs(),
            best: m.best,
            improvement_pct: 100.0 * m.total_improvement(),
        });

        let mut ex_runner = super::cases::sim_runner(w, cluster);
        let e = exhaustive(&mut ex_runner);
        rows.push(AblationRow {
            workload: w.name(),
            method: "exhaustive-grid",
            runs: e.trials.len() + 1,
            best: e.best,
            improvement_pct: 100.0 * e.total_improvement(),
        });

        for budget in [10usize, 30] {
            let mut r_runner = super::cases::sim_runner(w, cluster);
            let r = random_search(&mut r_runner, budget, 0xAB1A ^ budget as u64);
            rows.push(AblationRow {
                workload: w.name(),
                method: if budget == 10 { "random-10" } else { "random-30" },
                runs: budget + 1,
                best: r.best,
                improvement_pct: 100.0 * r.total_improvement(),
            });
        }
    }
    rows
}

/// Threshold-sensitivity sweep (the paper: "the methodology can be
/// employed in a less restrictive manner, where a configuration is
/// chosen … if the improvement exceeds a threshold, e.g. 5% or 10%"):
/// how do the kept-setting count and the final improvement move with the
/// threshold?
pub fn threshold_sweep(workload: Workload, cluster: &ClusterSpec) -> Table {
    let mut t = Table {
        title: format!("Threshold sensitivity — {} (Fig-4 methodology)", workload.name()),
        header: vec![
            "threshold".into(),
            "kept settings".into(),
            "best (s)".into(),
            "improvement".into(),
            "runs".into(),
        ],
        rows: Vec::new(),
    };
    for thr in [0.0, 0.02, 0.05, 0.10, 0.20] {
        let mut runner = super::cases::sim_runner(workload, cluster);
        let out = tune(&mut runner, &TuneOpts { threshold: thr, ..TuneOpts::default() });
        t.rows.push(vec![
            format!("{:.0}%", thr * 100.0),
            out.trials.iter().filter(|x| x.kept).count().to_string(),
            format!("{:.1}", out.best),
            format!("{:.1}%", 100.0 * out.total_improvement()),
            out.runs().to_string(),
        ]);
    }
    t
}

/// Render as markdown.
pub fn ablation_table(rows: &[AblationRow]) -> Table {
    Table {
        title: "E8 — search-strategy ablation (lower best-runtime is better)".into(),
        header: vec![
            "workload".into(),
            "method".into(),
            "runs".into(),
            "best (s)".into(),
            "improvement".into(),
        ],
        rows: rows
            .iter()
            .map(|r| {
                vec![
                    r.workload.into(),
                    r.method.into(),
                    r.runs.to_string(),
                    format!("{:.1}", r.best),
                    format!("{:.1}%", r.improvement_pct),
                ]
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lower thresholds can only keep more (or equal) settings and can
    /// only do as well or better.
    #[test]
    fn threshold_sweep_is_monotone() {
        let cluster = ClusterSpec::mini();
        let t = threshold_sweep(Workload::MiniSortByKey, &cluster);
        assert_eq!(t.rows.len(), 5);
        let best: Vec<f64> =
            t.rows.iter().map(|r| r[2].parse().unwrap()).collect();
        let kept: Vec<u32> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        for w in best.windows(2) {
            assert!(w[0] <= w[1] + 1e-6, "best must be monotone in threshold: {best:?}");
        }
        for w in kept.windows(2) {
            assert!(w[0] >= w[1], "kept count must not grow with threshold: {kept:?}");
        }
    }

    /// The headline property on the mini workload: the methodology's best
    /// is within a modest factor of the exhaustive optimum at ~20× fewer
    /// runs.
    #[test]
    fn methodology_close_to_exhaustive_on_mini() {
        let cluster = ClusterSpec::mini();
        let rows = ablation(&[Workload::MiniSortByKey], &cluster);
        let method = rows.iter().find(|r| r.method == "fig4-methodology").unwrap();
        let full = rows.iter().find(|r| r.method == "exhaustive-grid").unwrap();
        assert!(method.runs <= 10);
        assert!(full.runs >= 200);
        assert!(
            method.best <= full.best * 1.25,
            "methodology {:.2}s vs exhaustive {:.2}s",
            method.best,
            full.best
        );
        let t = ablation_table(&rows);
        assert_eq!(t.rows.len(), 4);
    }
}
