//! Experiment drivers: one entry per figure/table of the paper's
//! evaluation (see DESIGN.md §3 for the experiment index).
//!
//! * [`sensitivity`] — the §4 parameter sweeps (Figs 1, 2, 3).
//! * [`table2`] — mean |deviation| per parameter per benchmark.
//! * [`cases`] — the §5 case studies (methodology end-to-end).
//! * [`ablation`] — E8: methodology vs exhaustive vs random search.
//! * [`tenancy`] — N concurrent (identical or mixed) jobs on one
//!   cluster, FIFO vs FAIR with weighted pools, plus the busy-cluster
//!   tuning runner (`spark.scheduler.mode` through the event core).
//! * [`straggler`] — jittered-cluster speculation experiment
//!   (`spark.speculation` off vs on, and the straggler-aware tuner),
//!   plus the three-way mitigation comparison under a flaky node
//!   (task retry vs speculation vs node exclusion).
//! * [`faults`] — fault injection: a conf that wins on the clean
//!   cluster but aborts under failures, and the ensemble tuner finding
//!   a failure-robust incumbent.
//! * [`service`] — the tuning-service stress scenarios: M tenants × N
//!   apps through the memoized session server (cold vs warm, dedup and
//!   bit-identical-outcome checks, at any router shard count), plus
//!   the saturation mode (1k+ sessions, windowed admission with
//!   per-tenant fairness caps, `BENCH_service.json` trendlines).
//! * [`transfer`] — cross-workload evidence transfer: train N tenants,
//!   then warm-start a held-out similar workload and show it reaches
//!   the cold methodology's final quality in strictly fewer runs.
//!
//! Protocol follows the paper: each configuration is run with ≥5
//! repetition seeds and the **median** is reported; the baseline for the
//! sweeps is the default configuration *with the KryoSerializer* ("the
//! experiments that follow were conducted with the KryoSerializer"),
//! except the serializer row itself which compares Java against it.

pub mod ablation;
pub mod cases;
pub mod faults;
pub mod service;
pub mod straggler;
pub mod tenancy;
pub mod transfer;

use crate::cluster::ClusterSpec;
use crate::conf::SparkConf;
use crate::engine::{prepare, run_planned, Job, JobPlan};
use crate::report::{Bar, Figure, Table};
use crate::sim::SimOpts;
use crate::util::stats::{mean_abs_deviation_pct, Summary};
use crate::workloads::Workload;
use std::sync::Arc;

/// Repetitions per configuration ("at least five times … the median value
/// is reported").
pub const REPS: u64 = 5;

/// Run `job` under `conf` for [`REPS`] seeds; returns the median runtime,
/// or `None` if the configuration crashes (crashes are deterministic —
/// they depend on memory geometry, not jitter). Sweeps that evaluate one
/// job under many configurations should [`prepare`] once and call
/// [`median_run_planned`].
pub fn median_run(job: &Job, conf: &SparkConf, cluster: &ClusterSpec) -> Option<f64> {
    let plan = prepare(job).ok()?;
    median_run_planned(&plan, conf, cluster)
}

/// [`median_run`] over a shared plan (plan-once / price-many).
pub fn median_run_planned(
    plan: &Arc<JobPlan>,
    conf: &SparkConf,
    cluster: &ClusterSpec,
) -> Option<f64> {
    let mut durations = Vec::with_capacity(REPS as usize);
    for rep in 0..REPS {
        let r = run_planned(
            plan,
            conf,
            cluster,
            &SimOpts { jitter: 0.04, seed: 0xA5EED + rep, straggler: None },
        );
        if r.crashed.is_some() {
            return None;
        }
        durations.push(r.duration);
    }
    Some(Summary::from(durations).median())
}

/// One sweep variant: a parameter's test setting(s) applied on top of the
/// Kryo baseline.
#[derive(Clone, Copy, Debug)]
pub struct Variant {
    /// Table-2 row this variant belongs to.
    pub param: &'static str,
    /// Bar label, e.g. `manager=hash`.
    pub label: &'static str,
    pub settings: &'static [(&'static str, &'static str)],
}

/// The §4 sweep: every tested value of the 12 parameters (Figs 1–3 bars),
/// in the paper's bottom-to-top order for Fig 1.
pub const VARIANTS: &[Variant] = &[
    Variant {
        param: "spark.shuffle.manager",
        label: "manager=hash",
        settings: &[("spark.shuffle.manager", "hash")],
    },
    Variant {
        param: "spark.shuffle.manager",
        label: "manager=tungsten-sort",
        settings: &[("spark.shuffle.manager", "tungsten-sort")],
    },
    Variant {
        param: "shuffle/storage.memoryFraction",
        label: "memoryFraction=0.4/0.4",
        settings: &[
            ("spark.shuffle.memoryFraction", "0.4"),
            ("spark.storage.memoryFraction", "0.4"),
        ],
    },
    Variant {
        param: "shuffle/storage.memoryFraction",
        label: "memoryFraction=0.1/0.7",
        settings: &[
            ("spark.shuffle.memoryFraction", "0.1"),
            ("spark.storage.memoryFraction", "0.7"),
        ],
    },
    Variant {
        param: "spark.reducer.maxSizeInFlight",
        label: "maxSizeInFlight=96m",
        settings: &[("spark.reducer.maxSizeInFlight", "96m")],
    },
    Variant {
        param: "spark.reducer.maxSizeInFlight",
        label: "maxSizeInFlight=24m",
        settings: &[("spark.reducer.maxSizeInFlight", "24m")],
    },
    Variant {
        param: "spark.shuffle.file.buffer",
        label: "file.buffer=96k",
        settings: &[("spark.shuffle.file.buffer", "96k")],
    },
    Variant {
        param: "spark.shuffle.file.buffer",
        label: "file.buffer=15k",
        settings: &[("spark.shuffle.file.buffer", "15k")],
    },
    Variant {
        param: "spark.shuffle.compress",
        label: "shuffle.compress=false",
        settings: &[("spark.shuffle.compress", "false")],
    },
    Variant {
        param: "spark.io.compress.codec",
        label: "codec=lzf",
        settings: &[("spark.io.compression.codec", "lzf")],
    },
    Variant {
        param: "spark.io.compress.codec",
        label: "codec=lz4",
        settings: &[("spark.io.compression.codec", "lz4")],
    },
    Variant {
        param: "spark.shuffle.consolidateFiles",
        label: "consolidateFiles=true",
        settings: &[("spark.shuffle.consolidateFiles", "true")],
    },
    Variant {
        param: "spark.rdd.compress",
        label: "rdd.compress=true",
        settings: &[("spark.rdd.compress", "true")],
    },
    Variant {
        param: "spark.shuffle.io.preferDirectBufs",
        label: "preferDirectBufs=false",
        settings: &[("spark.shuffle.io.preferDirectBufs", "false")],
    },
    Variant {
        param: "spark.shuffle.spill.compress",
        label: "spill.compress=false",
        settings: &[("spark.shuffle.spill.compress", "false")],
    },
];

/// The Kryo baseline configuration of §4.
pub fn kryo_baseline() -> SparkConf {
    SparkConf::default().with("spark.serializer", "org.apache.spark.serializer.KryoSerializer")
}

/// Sensitivity sweep for one workload (Figs 1–3): every [`VARIANTS`] bar
/// plus the Java-serializer bar, against the Kryo baseline.
pub fn sensitivity(workload: Workload, cluster: &ClusterSpec) -> Figure {
    let plan = prepare(&workload.job()).expect("sweep workloads plan cleanly");
    let base_conf = kryo_baseline();
    let baseline = median_run_planned(&plan, &base_conf, cluster)
        .expect("the Kryo default baseline must not crash");

    let mut bars = Vec::with_capacity(VARIANTS.len() + 1);
    // Serializer bar: Java vs the Kryo baseline.
    bars.push(Bar {
        label: "serializer=java (default)".into(),
        value: median_run_planned(&plan, &SparkConf::default(), cluster),
    });
    for v in VARIANTS {
        let mut conf = base_conf.clone();
        for (k, val) in v.settings {
            conf.set(k, val).expect("variant settings are valid");
        }
        bars.push(Bar { label: v.label.into(), value: median_run_planned(&plan, &conf, cluster) });
    }
    Figure {
        id: figure_id(workload).into(),
        title: format!("Impact of all parameters for {}", workload.name()),
        baseline_label: "kryo default (baseline)".into(),
        baseline,
        bars,
    }
}

fn figure_id(w: Workload) -> &'static str {
    match w {
        Workload::SortByKey1B => "fig1",
        Workload::Shuffling400G => "fig2",
        Workload::KMeans100M => "fig3-top",
        Workload::KMeans200M => "fig3-bottom",
        _ => "sweep",
    }
}

/// Paper Table 2 reference values (percent mean |deviation|), for
/// side-by-side reporting.
pub const TABLE2_PAPER: &[(&str, [f64; 3])] = &[
    ("spark.serializer", [26.6, 9.2, 2.5]),
    ("shuffle/storage.memoryFraction", [13.1, 11.9, 8.3]),
    ("spark.reducer.maxSizeInFlight", [5.5, 5.7, 11.5]),
    ("spark.shuffle.file.buffer", [6.3, 11.6, 6.9]),
    ("spark.shuffle.compress", [137.5, 182.0, 2.5]),
    ("spark.io.compress.codec", [2.5, 18.0, 6.1]),
    ("spark.shuffle.consolidateFiles", [13.0, 11.0, 7.7]),
    ("spark.rdd.compress", [2.5, 2.5, 5.0]),
    ("spark.shuffle.io.preferDirectBufs", [5.6, 9.9, 2.5]),
    ("spark.shuffle.spill.compress", [2.5, 6.1, 2.5]),
];

/// Compute Table 2: mean |deviation| from the Kryo baseline per parameter
/// per benchmark (sort-by-key, shuffling, k-means-100M), measured next to
/// the paper's values. Crashed variants are excluded from the mean (the
/// paper's 0.1/0.7 rows crashed too).
pub fn table2(cluster: &ClusterSpec) -> Table {
    let benches =
        [Workload::SortByKey1B, Workload::Shuffling400G, Workload::KMeans100M];
    // Collect per-bench (baseline, label→median) maps.
    let mut per_bench: Vec<(f64, Vec<(&'static str, Option<f64>)>)> = Vec::new();
    let mut java_devs: Vec<f64> = Vec::new();
    for w in benches {
        let plan = prepare(&w.job()).expect("table-2 workloads plan cleanly");
        let base =
            median_run_planned(&plan, &kryo_baseline(), cluster).expect("baseline crash");
        let mut rows = Vec::new();
        for v in VARIANTS {
            let mut conf = kryo_baseline();
            for (k, val) in v.settings {
                conf.set(k, val).unwrap();
            }
            rows.push((v.param, median_run_planned(&plan, &conf, cluster)));
        }
        let java = median_run_planned(&plan, &SparkConf::default(), cluster);
        java_devs.push(match java {
            Some(j) => 100.0 * ((j - base) / base).abs(),
            None => f64::NAN,
        });
        per_bench.push((base, rows));
    }

    let mut table = Table {
        title: "Table 2 — Average parameter impact (mean |deviation| from Kryo baseline, %)"
            .into(),
        header: vec![
            "parameter".into(),
            "sort-by-key".into(),
            "shuffling".into(),
            "k-means".into(),
            "average".into(),
            "paper avg".into(),
        ],
        rows: Vec::new(),
    };

    for (param, paper) in TABLE2_PAPER {
        let mut measured = [0.0f64; 3];
        if *param == "spark.serializer" {
            for (i, d) in java_devs.iter().enumerate() {
                measured[i] = *d;
            }
        } else {
            for (i, (base, rows)) in per_bench.iter().enumerate() {
                let vals: Vec<f64> = rows
                    .iter()
                    .filter(|(p, _)| p == param)
                    .filter_map(|(_, v)| *v)
                    .collect();
                measured[i] = mean_abs_deviation_pct(*base, &vals);
            }
        }
        let avg = measured.iter().copied().filter(|v| v.is_finite()).sum::<f64>()
            / measured.iter().filter(|v| v.is_finite()).count().max(1) as f64;
        let paper_avg = paper.iter().sum::<f64>() / 3.0;
        table.rows.push(vec![
            param.to_string(),
            fmt_pct(measured[0]),
            fmt_pct(measured[1]),
            fmt_pct(measured[2]),
            fmt_pct(avg),
            format!("{paper_avg:.1}%"),
        ]);
    }
    table
}

fn fmt_pct(v: f64) -> String {
    if v.is_nan() {
        "n/a".into()
    } else if v < 5.0 {
        format!("<5% ({v:.1}%)")
    } else {
        format!("{v:.1}%")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run;

    fn mn() -> ClusterSpec {
        ClusterSpec::marenostrum()
    }

    /// Single-seed helper for shape tests (REPS medians are slow in debug).
    fn once(job: &Job, conf: &SparkConf) -> Option<f64> {
        let r = run(job, conf, &mn(), &SimOpts { jitter: 0.0, seed: 1, straggler: None });
        if r.crashed.is_some() {
            None
        } else {
            Some(r.duration)
        }
    }

    fn variant_conf(label: &str) -> SparkConf {
        let v = VARIANTS.iter().find(|v| v.label == label).unwrap();
        let mut conf = kryo_baseline();
        for (k, val) in v.settings {
            conf.set(k, val).unwrap();
        }
        conf
    }

    /// E1 shape assertions — who wins/loses on Fig 1 (sort-by-key).
    #[test]
    fn fig1_shapes() {
        let job = Workload::SortByKey1B.job();
        let base = once(&job, &kryo_baseline()).unwrap();
        // Java serializer notably slower (paper: ~25%).
        let java = once(&job, &SparkConf::default()).unwrap();
        let java_gap = (java - base) / base;
        assert!(java_gap > 0.10 && java_gap < 0.50, "java gap {java_gap:.3}");
        // Both alternate managers beat sort.
        let hash = once(&job, &variant_conf("manager=hash")).unwrap();
        let tung = once(&job, &variant_conf("manager=tungsten-sort")).unwrap();
        assert!(hash < base, "hash {hash} !< base {base}");
        assert!(tung < base, "tungsten {tung} !< base {base}");
        // 0.4/0.4 helps a little; 0.1/0.7 crashes.
        let mf44 = once(&job, &variant_conf("memoryFraction=0.4/0.4")).unwrap();
        assert!(mf44 < base, "0.4/0.4 {mf44} !< {base}");
        assert!(once(&job, &variant_conf("memoryFraction=0.1/0.7")).is_none(), "0.1/0.7 must crash");
        // Disabling shuffle compression degrades by >100%.
        let nc = once(&job, &variant_conf("shuffle.compress=false")).unwrap();
        assert!(nc > base * 1.9, "no-compress {nc} vs {base}");
        // Codecs ≈ neutral on sort-by-key.
        let lzf = once(&job, &variant_conf("codec=lzf")).unwrap();
        assert!((lzf - base).abs() / base < 0.10, "lzf dev {}", (lzf - base) / base);
    }

    /// E2 shape assertions — Fig 2 (shuffling): hash loses, tungsten wins,
    /// lz4 hurts, small file buffer hurts.
    #[test]
    fn fig2_shapes() {
        let job = Workload::Shuffling400G.job();
        let base = once(&job, &kryo_baseline()).unwrap();
        let hash = once(&job, &variant_conf("manager=hash")).unwrap();
        assert!(hash > base * 1.05, "hash should lose at 400GB: {hash} vs {base}");
        let tung = once(&job, &variant_conf("manager=tungsten-sort")).unwrap();
        assert!(tung < base, "tungsten {tung} !< {base}");
        let lz4 = once(&job, &variant_conf("codec=lz4")).unwrap();
        assert!(lz4 > base * 1.08, "lz4 {lz4} vs {base}");
        let lzf = once(&job, &variant_conf("codec=lzf")).unwrap();
        assert!((lzf - base).abs() / base < 0.10, "lzf ≈ baseline");
        let small_buf = once(&job, &variant_conf("file.buffer=15k")).unwrap();
        assert!(small_buf > base * 1.03, "15k buffer {small_buf} vs {base}");
        assert!(once(&job, &variant_conf("memoryFraction=0.1/0.7")).is_none());
    }

    /// E3 shape assertions — Fig 3 (k-means): everything within ~10%.
    #[test]
    fn fig3_shapes() {
        let job = Workload::KMeans100M.job();
        let base = once(&job, &kryo_baseline()).unwrap();
        for v in VARIANTS {
            let mut conf = kryo_baseline();
            for (k, val) in v.settings {
                conf.set(k, val).unwrap();
            }
            if let Some(t) = once(&job, &conf) {
                let dev = (t - base).abs() / base;
                assert!(dev < 0.12, "{}: k-means dev {:.3} too large", v.label, dev);
            }
            // (0.1/0.7 may legitimately run OR crash the tiny k-means
            // shuffle; the paper shows bars for it, so assert it runs:)
        }
        let mf17 = once(&job, &variant_conf("memoryFraction=0.1/0.7"));
        assert!(mf17.is_some(), "k-means must survive 0.1/0.7");
    }

    #[test]
    fn median_reps_are_deterministic() {
        let job = Workload::MiniSortByKey.job();
        let a = median_run(&job, &SparkConf::default(), &ClusterSpec::mini());
        let b = median_run(&job, &SparkConf::default(), &ClusterSpec::mini());
        assert_eq!(a, b);
        assert!(a.unwrap() > 0.0);
    }

    #[test]
    fn sensitivity_figure_structure() {
        // Mini workload keeps this fast; structural assertions only.
        let fig = sensitivity(Workload::MiniSortByKey, &ClusterSpec::mini());
        assert_eq!(fig.bars.len(), VARIANTS.len() + 1);
        assert!(fig.baseline > 0.0);
        let ascii = fig.to_ascii(100);
        assert!(ascii.contains("baseline"));
    }
}
