//! The cross-workload evidence-transfer scenario: train a
//! warm-start-enabled [`TuningService`] on N tenants' sessions, then
//! tune a **held-out similar workload** warm and compare against the
//! same workload tuned cold.
//!
//! The claim under test (ROADMAP "cross-workload evidence transfer",
//! and the retrieval-tuning line of PAPERS.md): evidence from *similar*
//! workloads lets a new application reach the cold methodology's final
//! configuration quality in **strictly fewer** trial evaluations. The
//! comparison is exact, not statistical — the held-out job, cluster,
//! and simulator seed are identical across the cold and warm sessions,
//! so equal final configurations price to bit-identical durations
//! through the same fingerprinted trial path, and the CLI `transfer`
//! smoke (CI) asserts:
//!
//! * a neighbor was actually found and used (`warm_from`),
//! * the warm session ran strictly fewer trials than the cold one,
//! * the warm final duration is ≤ the cold final duration,
//! * outcomes reproduce bit-for-bit across service worker counts.

use crate::cluster::ClusterSpec;
use crate::engine::{prepare, run_planned, Job};
use crate::report::Table;
use crate::service::{ServiceOpts, SessionRequest, TuningService};
use crate::sim::SimOpts;
use crate::tuner::{tune, TuneOpts, TuneOutcome};
use crate::workloads;

/// Transfer-scenario sizing.
#[derive(Clone, Copy, Debug)]
pub struct TransferOpts {
    /// Training sessions (tenants) served before the held-out workload.
    /// The catalog cycles shuffle-heavy / iterative-cached /
    /// combine-heavy families at growing scales, so the index holds
    /// both similar and dissimilar evidence.
    pub tenants: u32,
    /// Service worker threads.
    pub workers: usize,
    /// kNN admission threshold (profile distance).
    pub threshold: f64,
}

impl Default for TransferOpts {
    fn default() -> Self {
        TransferOpts { tenants: 6, workers: 4, threshold: 0.25 }
    }
}

/// Every session in the scenario shares one simulator setup: the trial
/// streams differ only in their jobs, exactly like one tenant fleet on
/// one cluster.
fn sim() -> SimOpts {
    SimOpts { jitter: 0.04, seed: 0x7A1F, straggler: None }
}

fn tune_opts() -> TuneOpts {
    TuneOpts { short_version: true, ..TuneOpts::default() }
}

/// Training tenant `t`'s application: families cycle, scales grow every
/// full cycle (mirrors [`crate::experiments::service`]'s catalog shape;
/// partitions stay fixed so family similarity dominates the profile).
pub fn training_job(t: u32) -> Job {
    let scale = 1 + t as u64 / 3;
    match t % 3 {
        0 => workloads::sort_by_key(1_000_000 * scale, 16),
        1 => workloads::kmeans(50_000 * scale, 20, 4, 2, 16),
        _ => workloads::aggregate_by_key(1_500_000 * scale, 40_000, 16),
    }
}

/// The held-out workload: a sort-by-key at a scale the training
/// catalog never saw — similar to the trained sort-by-key tenants
/// (1 % more records than the nearest, `tenant3`'s 2 M instance, so
/// the neighbor's keep/reject signs transfer robustly), the same
/// application to no one.
pub fn held_out_job() -> Job {
    workloads::sort_by_key(2_020_000, 16)
}

/// Outcome of the transfer scenario.
#[derive(Clone, Debug)]
pub struct TransferReport {
    pub opts: TransferOpts,
    /// Sessions recorded in the service's index after training.
    pub trained: usize,
    /// The neighbor the held-out session transferred from (None = the
    /// warm path fell back cold — a scenario failure).
    pub warm_from: Option<String>,
    /// The held-out workload tuned cold (the paper's methodology).
    pub cold: TuneOutcome,
    /// The held-out workload tuned through the warm-started service.
    pub warm: TuneOutcome,
}

impl TransferReport {
    /// Trial evaluations saved by the transfer (runs include the
    /// baseline run both sessions pay).
    pub fn runs_saved(&self) -> i64 {
        self.cold.runs() as i64 - self.warm.runs() as i64
    }

    /// The scenario's acceptance predicate: evidence was found and
    /// used, strictly fewer runs, and final quality no worse than cold
    /// (both finite — a crashed final configuration fails).
    pub fn transfer_won(&self) -> bool {
        self.warm_from.is_some()
            && self.warm.runs() < self.cold.runs()
            && self.warm.best.is_finite()
            && self.cold.best.is_finite()
            && self.warm.best <= self.cold.best
    }
}

/// Run the scenario: train `opts.tenants` sessions, then serve the
/// held-out workload warm; the cold control is a direct [`tune`] on
/// the identical job/sim (bit-identical to a cold serve by the
/// service-parity invariant).
pub fn transfer_experiment(opts: &TransferOpts, cluster: &ClusterSpec) -> TransferReport {
    // ---- cold control ----
    let held_out = held_out_job();
    let plan = prepare(&held_out).expect("held-out workload plans cleanly");
    let mut cold_runner = |conf: &crate::conf::SparkConf| {
        run_planned(&plan, conf, cluster, &sim()).effective_duration()
    };
    let cold = tune(&mut cold_runner, &tune_opts());

    // ---- train ----
    let svc = TuningService::new(
        cluster.clone(),
        ServiceOpts {
            workers: opts.workers,
            warm_start: true,
            warm_threshold: opts.threshold,
            ..ServiceOpts::default()
        },
    );
    let training: Vec<SessionRequest> = (0..opts.tenants)
        .map(|t| SessionRequest {
            name: format!("tenant{t}/{}", training_job(t).name),
            job: training_job(t),
            tune: tune_opts(),
            sim: sim(),
        })
        .collect();
    svc.serve(&training);
    let trained = svc.profiled_sessions();

    // ---- transfer to the held-out workload ----
    let warm_session = svc
        .serve(&[SessionRequest {
            name: "held-out/sort-by-key".into(),
            job: held_out,
            tune: tune_opts(),
            sim: sim(),
        }])
        .remove(0);

    TransferReport {
        opts: *opts,
        trained,
        warm_from: warm_session.warm_from,
        cold,
        warm: warm_session.outcome,
    }
}

/// Render the transfer report as a metric table.
pub fn transfer_table(r: &TransferReport) -> Table {
    Table::two_col(
        format!(
            "Evidence transfer — {} training tenants, threshold {:.2}",
            r.opts.tenants, r.opts.threshold
        ),
        &[
            ("sessions recorded in the index", r.trained.to_string()),
            (
                "held-out warm-started from",
                r.warm_from.clone().unwrap_or_else(|| "<no neighbor in range>".into()),
            ),
            ("cold runs (trials + baseline)", r.cold.runs().to_string()),
            ("warm runs (trials + baseline)", r.warm.runs().to_string()),
            ("runs saved by transfer", r.runs_saved().to_string()),
            ("cold final duration", format!("{:.3}s", r.cold.best)),
            ("warm final duration", format!("{:.3}s", r.warm.best)),
            (
                "final configurations agree",
                (r.warm.best_conf == r.cold.best_conf).to_string(),
            ),
            ("transfer won (fewer runs, quality ≤ cold)", r.transfer_won().to_string()),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::outcomes_identical;

    #[test]
    fn transfer_reaches_cold_quality_in_fewer_runs() {
        let r = transfer_experiment(&TransferOpts::default(), &ClusterSpec::mini());
        assert_eq!(r.trained, 6);
        let from = r.warm_from.as_deref().expect("a trained sort-by-key must be in range");
        assert!(from.contains("sort-by-key"), "nearest neighbor is {from:?}");
        assert!(
            r.warm.runs() < r.cold.runs(),
            "warm {} vs cold {} runs",
            r.warm.runs(),
            r.cold.runs()
        );
        assert_eq!(r.warm.best_conf, r.cold.best_conf, "transfer must land on the cold conf");
        assert_eq!(
            r.warm.best.to_bits(),
            r.cold.best.to_bits(),
            "same conf on the same trial key prices bit-identically"
        );
        assert!(r.transfer_won());
    }

    #[test]
    fn transfer_is_deterministic_across_thread_counts() {
        let base = transfer_experiment(
            &TransferOpts { workers: 1, ..TransferOpts::default() },
            &ClusterSpec::mini(),
        );
        for workers in [4usize, 8] {
            let r = transfer_experiment(
                &TransferOpts { workers, ..TransferOpts::default() },
                &ClusterSpec::mini(),
            );
            assert_eq!(r.warm_from, base.warm_from, "workers={workers}");
            assert!(outcomes_identical(&r.cold, &base.cold), "cold diverged, workers={workers}");
            assert!(outcomes_identical(&r.warm, &base.warm), "warm diverged, workers={workers}");
        }
    }

    #[test]
    fn threshold_zero_disables_transfer() {
        // With an impossible threshold nothing is in range: the
        // held-out session runs cold through the warm-enabled service
        // and the report says so.
        let r = transfer_experiment(
            &TransferOpts { threshold: 0.0, ..TransferOpts::default() },
            &ClusterSpec::mini(),
        );
        assert!(r.warm_from.is_none());
        assert_eq!(r.warm.runs(), r.cold.runs());
        assert!(outcomes_identical(&r.warm, &r.cold), "cold fallback must equal direct tune");
        assert!(!r.transfer_won());
    }

    #[test]
    fn table_reports_the_headline_numbers() {
        let r = transfer_experiment(&TransferOpts::default(), &ClusterSpec::mini());
        let md = transfer_table(&r).to_markdown();
        assert!(md.contains("runs saved by transfer"), "{md}");
        assert!(md.contains("held-out warm-started from"), "{md}");
        assert!(md.contains("| transfer won (fewer runs, quality ≤ cold) | true |"), "{md}");
    }
}
