//! Straggler / speculative-execution experiment: the paper's tuning
//! space includes `spark.speculation`, but its testbed was healthy; this
//! driver prices a **jittered cluster** — a heavy-tailed per-task
//! slowdown ([`Straggler`]) on top of the usual ±4 % jitter — and shows
//! the knob paying for itself: with speculation on, backup copies of the
//! tail tasks win on healthy nodes and the makespan recovers ≥ 2×
//! (the >10× spirit of the paper's case studies, applied to the
//! straggler regime).
//!
//! Also runs the Fig-4-style decision list with the straggler-aware
//! steps ([`crate::tuner::TuneOpts::straggler_aware`]) so the tuner can
//! *discover* the speculation/locality settings by trial and error.
//!
//! [`mitigation_experiment`] completes the picture for *crashing* (not
//! merely slow) nodes: the same probe under a black-hole node, priced
//! three ways — task retries alone, speculation, and node exclusion —
//! showing that speculation targets slow tasks and cannot save a job
//! from a node that fails every commit, while exclusion can.

use crate::cluster::ClusterSpec;
use crate::conf::SparkConf;
use crate::engine::{prepare, run_planned, run_planned_faulted, JobResult};
use crate::report::Table;
use crate::sim::{FaultPlan, FlakyNode, SimOpts, Straggler};
use crate::tuner::{tune, TuneOpts, TuneOutcome};
use crate::workloads;

/// Outcome of one speculation-off vs speculation-on comparison on a
/// jittered cluster.
#[derive(Clone, Debug)]
pub struct StragglerOutcome {
    /// The straggler model applied to every task draw.
    pub straggler: Straggler,
    /// Run with `spark.speculation=false` (the 1.5.2 default).
    pub off: JobResult,
    /// Run with `spark.speculation=true`, default multiplier/quantile.
    pub on: JobResult,
}

impl StragglerOutcome {
    /// Makespan ratio off/on — how much speculation recovered.
    pub fn recovery(&self) -> f64 {
        if self.on.duration > 0.0 {
            self.off.duration / self.on.duration
        } else {
            f64::INFINITY
        }
    }

    /// Total speculative copies launched in the `on` run.
    pub fn clones(&self) -> usize {
        self.on.stages.iter().map(|s| s.speculated).sum()
    }
}

/// Fixed seed: the experiment is a deterministic function of its sizes
/// and straggler model.
const SEED: u64 = 0x57A6;

/// Run the straggler probe (`records` over `partitions` pure-CPU tasks)
/// with and without speculation on a cluster whose tasks straggle per
/// `straggler`.
pub fn straggler_experiment(
    records: u64,
    partitions: u32,
    straggler: Straggler,
    cluster: &ClusterSpec,
) -> StragglerOutcome {
    let plan = prepare(&workloads::straggler_probe(records, partitions))
        .expect("straggler probe plans cleanly");
    let opts = SimOpts { jitter: 0.04, seed: SEED, straggler: Some(straggler) };
    let off = run_planned(&plan, &SparkConf::default(), cluster, &opts);
    let on_conf = SparkConf::default().with("spark.speculation", "true");
    let on = run_planned(&plan, &on_conf, cluster, &opts);
    StragglerOutcome { straggler, off, on }
}

/// Run the straggler-aware Fig-4 decision list on the jittered cluster:
/// the tuner must find a locality/speculation configuration at least as
/// good as the defaults within the extended trial budget (≤ 14 runs).
pub fn tune_under_stragglers(
    records: u64,
    partitions: u32,
    straggler: Straggler,
    cluster: &ClusterSpec,
) -> TuneOutcome {
    let plan = prepare(&workloads::straggler_probe(records, partitions))
        .expect("straggler probe plans cleanly");
    let opts = SimOpts { jitter: 0.04, seed: SEED, straggler: Some(straggler) };
    let mut runner =
        move |conf: &SparkConf| run_planned(&plan, conf, cluster, &opts).effective_duration();
    tune(&mut runner, &TuneOpts { straggler_aware: true, ..TuneOpts::default() })
}

/// Render the comparison as a markdown table.
pub fn straggler_table(o: &StragglerOutcome) -> Table {
    let mut t = Table {
        title: format!(
            "Straggler experiment — {:.0}% of tasks {:.0}x slower, speculation off vs on",
            o.straggler.prob * 100.0,
            o.straggler.factor
        ),
        header: vec![
            "spark.speculation".into(),
            "makespan (s)".into(),
            "backup copies".into(),
            "recovery".into(),
        ],
        rows: Vec::new(),
    };
    t.rows.push(vec![
        "false".into(),
        format!("{:.1}", o.off.duration),
        "0".into(),
        "1.0x".into(),
    ]);
    t.rows.push(vec![
        "true".into(),
        format!("{:.1}", o.on.duration),
        format!("{}", o.clones()),
        format!("{:.1}x", o.recovery()),
    ]);
    t
}

/// Outcome of the three-way mitigation comparison under a black-hole
/// node: the same probe priced with task retries alone (the defaults),
/// with speculation, and with node exclusion.
#[derive(Clone, Debug)]
pub struct MitigationOutcome {
    /// Defaults: `spark.task.maxFailures` retries are the only defense.
    pub retry: JobResult,
    /// `spark.speculation=true` on top of the defaults.
    pub speculation: JobResult,
    /// `spark.excludeOnFailure.enabled=true` on top of the defaults.
    pub exclusion: JobResult,
}

/// Price the straggler probe under a node that fails **every** commit
/// (crash probability 1.0 on node 1) three ways. Retries re-land on the
/// doomed node — block placement prefers it — so some task exhausts its
/// budget and the job aborts; speculation never fires because doomed
/// attempts are not slow, only fatal; exclusion removes the node after
/// `spark.excludeOnFailure.task.maxTaskAttemptsPerNode` failures and
/// the job finishes on the surviving capacity.
pub fn mitigation_experiment(
    records: u64,
    partitions: u32,
    cluster: &ClusterSpec,
) -> MitigationOutcome {
    let plan = prepare(&workloads::straggler_probe(records, partitions))
        .expect("straggler probe plans cleanly");
    let opts = SimOpts { jitter: 0.04, seed: SEED, straggler: None };
    let faults = FaultPlan {
        seed: SEED,
        task_crash_prob: 0.0,
        flaky: Some(FlakyNode { node: 1, crash_prob: 1.0 }),
        losses: Vec::new(),
    };
    let price = |conf: &SparkConf| run_planned_faulted(&plan, conf, cluster, &opts, &faults);
    MitigationOutcome {
        retry: price(&SparkConf::default()),
        speculation: price(&SparkConf::default().with("spark.speculation", "true")),
        exclusion: price(&SparkConf::default().with("spark.excludeOnFailure.enabled", "true")),
    }
}

/// Render the three-way comparison as a markdown table.
pub fn mitigation_table(o: &MitigationOutcome) -> Table {
    fn row(label: &str, r: &JobResult) -> Vec<String> {
        vec![
            label.into(),
            if r.crashed.is_some() { "aborted".into() } else { format!("{:.1}", r.duration) },
            format!("{}", r.sim.task_failures),
            format!("{}", r.stages.iter().map(|s| s.speculated).sum::<usize>()),
            format!("{}", r.sim.stage_aborts),
        ]
    }
    Table {
        title: "Mitigation under a black-hole node — retry vs speculation vs exclusion".into(),
        header: vec![
            "mitigation".into(),
            "makespan (s)".into(),
            "task failures".into(),
            "backup copies".into(),
            "stage aborts".into(),
        ],
        rows: vec![
            row("task retries (defaults)", &o.retry),
            row("speculation", &o.speculation),
            row("node exclusion", &o.exclusion),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run;

    /// Paper-scale sizing: ~1 s tasks, 2 waves over the 320-core
    /// testbed, ~2 % of tasks 8x slower.
    fn paper_scale() -> (u64, u32, Straggler) {
        (320_000_000, 640, Straggler { prob: 0.02, factor: 8.0 })
    }

    #[test]
    fn speculation_recovers_straggler_tail_2x() {
        // The acceptance bar: on the jittered cluster,
        // spark.speculation=true improves the makespan >= 2x vs
        // disabled, by racing backup copies of the tail tasks.
        let (records, partitions, straggler) = paper_scale();
        let o = straggler_experiment(
            records,
            partitions,
            straggler,
            &ClusterSpec::marenostrum(),
        );
        assert!(o.off.crashed.is_none() && o.on.crashed.is_none());
        assert!(o.clones() > 0, "the tail must be speculated");
        assert!(
            o.recovery() >= 2.0,
            "speculation must recover >= 2x: off {:.1}s on {:.1}s ({} clones)",
            o.off.duration,
            o.on.duration,
            o.clones()
        );
    }

    #[test]
    fn experiment_is_deterministic() {
        let s = Straggler { prob: 0.05, factor: 8.0 };
        let a = straggler_experiment(4_000_000, 64, s, &ClusterSpec::mini());
        let b = straggler_experiment(4_000_000, 64, s, &ClusterSpec::mini());
        assert_eq!(a.off.duration, b.off.duration);
        assert_eq!(a.on.duration, b.on.duration);
        assert_eq!(a.clones(), b.clones());
    }

    #[test]
    fn speculation_is_free_without_stragglers() {
        // Same probe, straggler model off: enabling speculation must not
        // change the makespan (no task crosses 1.5x the median) — the
        // knob is pure upside on this workload.
        let cluster = ClusterSpec::marenostrum();
        let job = workloads::straggler_probe(32_000_000, 640);
        let opts = SimOpts { jitter: 0.04, seed: SEED, straggler: None };
        let off = run(&job, &SparkConf::default(), &cluster, &opts);
        let on = run(
            &job,
            &SparkConf::default().with("spark.speculation", "true"),
            &cluster,
            &opts,
        );
        assert_eq!(on.stages.iter().map(|s| s.speculated).sum::<usize>(), 0);
        let dev = (on.duration - off.duration).abs() / off.duration.max(1e-12);
        assert!(dev < 1e-9, "speculation must be free on a healthy cluster: dev {dev:e}");
    }

    #[test]
    fn tuner_discovers_speculation_on_jittered_cluster() {
        // Acceptance: the Fig-4-style decision list with the
        // straggler-aware steps finds a locality/speculation config at
        // least as good as the defaults within the extended budget.
        let (records, partitions, straggler) = paper_scale();
        let out = tune_under_stragglers(
            records,
            partitions,
            straggler,
            &ClusterSpec::marenostrum(),
        );
        assert!(out.runs() <= 14, "used {} runs", out.runs());
        assert!(out.best <= out.baseline, "never worse than defaults by construction");
        assert!(
            out.best_conf.speculation,
            "speculation must be kept on the jittered cluster: {:?}",
            out.final_settings()
        );
        assert!(
            out.total_improvement() >= 0.5,
            "keeping speculation halves the makespan: {:.3}",
            out.total_improvement()
        );
    }

    #[test]
    fn exclusion_survives_a_black_hole_node_where_retries_and_speculation_abort() {
        let o = mitigation_experiment(4_000_000, 64, &ClusterSpec::mini());
        // Retries re-land on the doomed node (block placement prefers
        // it) until some task exhausts spark.task.maxFailures.
        assert!(
            o.retry.effective_duration().is_infinite(),
            "retries alone must abort: {:?}",
            o.retry.crashed
        );
        assert!(o.retry.sim.stage_aborts >= 1);
        // Speculation clones slow copies; doomed copies are not slow,
        // so it fares exactly as badly as retries alone.
        assert!(o.speculation.effective_duration().is_infinite());
        assert_eq!(
            o.speculation.stages.iter().map(|s| s.speculated).sum::<usize>(),
            0,
            "a crashing-but-not-slow copy must never be cloned"
        );
        // Exclusion removes the node after its charged failures and the
        // job finishes on the surviving 3/4 capacity.
        assert!(o.exclusion.crashed.is_none(), "{:?}", o.exclusion.crashed);
        assert!(o.exclusion.duration.is_finite() && o.exclusion.duration > 0.0);
        assert!(
            o.exclusion.sim.task_failures >= 2,
            "the node is excluded only after charged failures"
        );
        assert_eq!(o.exclusion.sim.stage_aborts, 0);
    }

    #[test]
    fn mitigation_experiment_is_deterministic() {
        let a = mitigation_experiment(2_000_000, 32, &ClusterSpec::mini());
        let b = mitigation_experiment(2_000_000, 32, &ClusterSpec::mini());
        assert_eq!(a.exclusion.duration.to_bits(), b.exclusion.duration.to_bits());
        assert_eq!(a.retry.crashed, b.retry.crashed);
        assert_eq!(a.exclusion.sim.task_failures, b.exclusion.sim.task_failures);
        assert_eq!(a.speculation.sim.task_failures, b.speculation.sim.task_failures);
    }

    #[test]
    fn mitigation_table_renders_three_rows() {
        let o = mitigation_experiment(2_000_000, 32, &ClusterSpec::mini());
        let md = mitigation_table(&o).to_markdown();
        assert!(md.contains("task retries (defaults)"));
        assert!(md.contains("speculation"));
        assert!(md.contains("node exclusion"));
        assert!(md.contains("aborted"), "the retry row must read as aborted:\n{md}");
    }

    #[test]
    fn table_renders_both_rows() {
        let o = straggler_experiment(
            2_000_000,
            32,
            Straggler { prob: 0.1, factor: 6.0 },
            &ClusterSpec::mini(),
        );
        let md = straggler_table(&o).to_markdown();
        assert!(md.contains("true"));
        assert!(md.contains("false"));
        assert!(md.contains("recovery"));
    }
}
