//! Multi-tenant experiment: N concurrent jobs on one cluster, FIFO vs
//! FAIR (`spark.scheduler.mode`).
//!
//! The paper tunes one application at a time on an otherwise idle
//! cluster; production clusters run many. This driver submits a batch of
//! jobs at `t = 0` through the event core ([`crate::engine::run_all`])
//! and reports per-job completion times, makespan, and completion-time
//! *spread* under both scheduling policies. The characteristic shapes:
//!
//! * **FIFO** — earlier-submitted jobs monopolize cores, so completion
//!   times stagger by submission order (first job ≈ its solo time, last
//!   job ≈ makespan; large spread);
//! * **FAIR** — running-task shares are balanced, so identical jobs
//!   finish bunched together near the makespan (small spread), each one
//!   individually slower than under FIFO.
//!
//! Makespan is work-conserving either way — the policies redistribute
//! latency, not throughput.

use crate::cluster::ClusterSpec;
use crate::conf::SparkConf;
use crate::engine::{prepare, run_all, run_all_planned, Job, JobPlan, MultiJobResult};
use crate::report::Table;
use crate::sim::{SchedulerMode, SimOpts};
use crate::workloads;
use std::sync::Arc;

/// One policy's outcome on a job batch.
#[derive(Clone, Debug)]
pub struct TenancyOutcome {
    pub mode: SchedulerMode,
    pub batch: MultiJobResult,
}

impl TenancyOutcome {
    /// Completion times of uncrashed jobs, in submission order.
    pub fn completions(&self) -> Vec<f64> {
        self.batch
            .results
            .iter()
            .filter(|r| r.crashed.is_none())
            .map(|r| r.duration)
            .collect()
    }

    /// Max − min completion time across uncrashed jobs (the fairness
    /// signature: large under FIFO, small under FAIR for identical jobs).
    pub fn spread(&self) -> f64 {
        let c = self.completions();
        let max = c.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let min = c.iter().copied().fold(f64::INFINITY, f64::min);
        if c.is_empty() {
            0.0
        } else {
            max - min
        }
    }
}

/// Run `jobs` concurrently under `mode` (overriding the configuration's
/// scheduler mode). Deterministic in `(conf, seed)`.
pub fn run_tenancy(
    jobs: &[Job],
    conf: &SparkConf,
    cluster: &ClusterSpec,
    mode: SchedulerMode,
    opts: &SimOpts,
) -> TenancyOutcome {
    let mut conf = conf.clone();
    conf.scheduler_mode = mode;
    TenancyOutcome { mode, batch: run_all(jobs, &conf, cluster, opts) }
}

/// The standard scenario: `n` concurrent tenants on the paper's cluster,
/// both policies. `mixed` swaps the identical sort-by-key tenants for
/// the heterogeneous sbk/k-means/agg batch.
pub fn tenancy_experiment(
    n: u32,
    records_per_job: u64,
    mixed: bool,
    cluster: &ClusterSpec,
) -> Vec<TenancyOutcome> {
    let jobs = if mixed {
        workloads::mixed_tenants(n, records_per_job, 640)
    } else {
        workloads::multi_tenant(n, records_per_job, 640)
    };
    let conf = SparkConf::default().with("spark.serializer", "kryo");
    SchedulerMode::ALL
        .iter()
        .map(|&mode| run_tenancy(&jobs, &conf, cluster, mode, &SimOpts::default()))
        .collect()
}

/// The background batch for tuner × tenancy: heterogeneous mixed tenants
/// at `records_per_job` scale (see [`busy_runner`]).
pub fn background_jobs(n: u32, records_per_job: u64, partitions: u32) -> Vec<Job> {
    workloads::mixed_tenants(n, records_per_job, partitions)
}

/// A tuning [`crate::tuner::Runner`] that prices each candidate on a
/// **busy** cluster: the target job is submitted at `t = 0` alongside
/// `background`, all under the candidate configuration (one shared conf
/// — the scheduler-mode knob therefore also shapes how the target
/// competes), and the target's effective duration is returned. Job 0 is
/// the target, so its jitter stream matches a solo run exactly.
pub fn busy_runner<'a>(
    target: Job,
    background: Vec<Job>,
    cluster: &'a ClusterSpec,
) -> impl FnMut(&SparkConf) -> f64 + 'a {
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    // Plan once, price many: the target and every background tenant are
    // planned a single time; each trial shares the `Arc<JobPlan>`s and
    // only re-prices them under the candidate configuration. If any job
    // is unplannable, fall back to the plan-per-trial path, which
    // reports the failure as a crash (INFINITY) instead of panicking —
    // the behavior tuners already handle.
    let plans: Option<Vec<Arc<JobPlan>>> = std::iter::once(&target)
        .chain(background.iter())
        .map(|j| prepare(j).ok())
        .collect();
    move |conf: &SparkConf| match &plans {
        Some(plans) => {
            run_all_planned(plans, conf, cluster, &opts).results[0].effective_duration()
        }
        None => {
            let mut jobs = Vec::with_capacity(1 + background.len());
            jobs.push(target.clone());
            jobs.extend(background.iter().cloned());
            run_all(&jobs, conf, cluster, &opts).results[0].effective_duration()
        }
    }
}

/// Render outcomes as a markdown table.
pub fn tenancy_table(outcomes: &[TenancyOutcome]) -> Table {
    let mut t = Table {
        title: "Multi-tenant scheduling — N concurrent jobs, FIFO vs FAIR".into(),
        header: vec![
            "mode".into(),
            "job".into(),
            "completion (s)".into(),
            "makespan (s)".into(),
            "spread (s)".into(),
        ],
        rows: Vec::new(),
    };
    for o in outcomes {
        for r in &o.batch.results {
            t.rows.push(vec![
                o.mode.to_string(),
                r.job.to_string(),
                match &r.crashed {
                    None => format!("{:.1}", r.duration),
                    Some(c) => format!("CRASH ({c})"),
                },
                format!("{:.1}", o.batch.makespan),
                format!("{:.1}", o.spread()),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::run;

    /// 4 identical small tenants on the mini cluster under both modes.
    fn mini_outcomes() -> (TenancyOutcome, TenancyOutcome, f64) {
        let cluster = ClusterSpec::mini();
        let jobs = workloads::multi_tenant(4, 2_000_000, 16);
        let conf = SparkConf::default();
        let opts = SimOpts::default();
        let solo = run(&jobs[0], &conf, &cluster, &opts);
        assert!(solo.crashed.is_none());
        let fifo = run_tenancy(&jobs, &conf, &cluster, SchedulerMode::Fifo, &opts);
        let fair = run_tenancy(&jobs, &conf, &cluster, SchedulerMode::Fair, &opts);
        (fifo, fair, solo.duration)
    }

    #[test]
    fn both_modes_run_four_tenants_uncrashed() {
        let (fifo, fair, _) = mini_outcomes();
        assert_eq!(fifo.completions().len(), 4);
        assert_eq!(fair.completions().len(), 4);
        assert!(fifo.batch.makespan > 0.0 && fair.batch.makespan > 0.0);
    }

    #[test]
    fn fifo_staggers_by_submission_order() {
        let (fifo, _, solo) = mini_outcomes();
        let c = fifo.completions();
        for w in c.windows(2) {
            assert!(
                w[0] <= w[1] + 1e-9,
                "FIFO completions must be ordered by submission: {c:?}"
            );
        }
        // The first tenant is barely slowed: FIFO gives it the cluster.
        assert!(
            c[0] < solo * 1.6,
            "FIFO first job {:.2}s vs solo {:.2}s — should be near-solo",
            c[0],
            solo
        );
    }

    #[test]
    fn fair_bunches_fifo_spreads() {
        let (fifo, fair, _) = mini_outcomes();
        // FAIR slows every individual job relative to FIFO's front-runner…
        assert!(
            fair.completions()[0] > fifo.completions()[0] * 1.3,
            "FAIR first job {:.2}s should be well above FIFO's {:.2}s",
            fair.completions()[0],
            fifo.completions()[0]
        );
        // …but evens them out: identical jobs finish bunched together.
        assert!(
            fair.spread() < fifo.spread() * 0.5,
            "FAIR spread {:.2}s !< half of FIFO spread {:.2}s",
            fair.spread(),
            fifo.spread()
        );
    }

    #[test]
    fn policies_are_work_conserving() {
        let (fifo, fair, solo) = mini_outcomes();
        // Same total work → comparable makespans (latency is
        // redistributed, not created), and neither beats 4× the solo
        // lower bound by much nor blows far past it.
        let ratio = fair.batch.makespan / fifo.batch.makespan;
        assert!(
            (0.6..1.7).contains(&ratio),
            "makespans diverged: fifo {:.2}s fair {:.2}s",
            fifo.batch.makespan,
            fair.batch.makespan
        );
        assert!(fifo.batch.makespan > solo * 1.5, "4 tenants must cost more than ~1 solo run");
    }

    #[test]
    fn table_renders_both_modes() {
        let cluster = ClusterSpec::mini();
        let jobs = workloads::multi_tenant(2, 1_000_000, 16);
        let conf = SparkConf::default();
        let outs: Vec<TenancyOutcome> = SchedulerMode::ALL
            .iter()
            .map(|&m| run_tenancy(&jobs, &conf, &cluster, m, &SimOpts::default()))
            .collect();
        let md = tenancy_table(&outs).to_markdown();
        assert!(md.contains("FIFO"));
        assert!(md.contains("FAIR"));
        assert!(md.contains("tenant0-"));
    }

    #[test]
    fn weighted_pools_bias_fair_completion_order() {
        // Two identical tenants under FAIR; giving tenant 0 weight 4
        // must finish it well before tenant 1, and before its own
        // completion in the even-share run.
        let cluster = ClusterSpec::mini();
        let conf = SparkConf::default();
        let opts = SimOpts::default();
        let even_jobs = workloads::multi_tenant(2, 2_000_000, 16);
        let mut weighted_jobs = even_jobs.clone();
        weighted_jobs[0] = weighted_jobs[0].clone().in_pool(4.0, 0);

        let even = run_tenancy(&even_jobs, &conf, &cluster, SchedulerMode::Fair, &opts);
        let weighted =
            run_tenancy(&weighted_jobs, &conf, &cluster, SchedulerMode::Fair, &opts);
        let wc = weighted.completions();
        assert!(
            wc[0] < wc[1] * 0.8,
            "weight-4 tenant must finish well first: {:.2}s vs {:.2}s",
            wc[0],
            wc[1]
        );
        assert!(
            wc[0] < even.completions()[0] * 0.9,
            "weight-4 beats its even-share self: {:.2}s vs {:.2}s",
            wc[0],
            even.completions()[0]
        );
    }

    #[test]
    fn mixed_tenancy_runs_both_modes() {
        let cluster = ClusterSpec::mini();
        let jobs = workloads::mixed_tenants(3, 1_000_000, 16);
        for mode in SchedulerMode::ALL {
            let o = run_tenancy(&jobs, &SparkConf::default(), &cluster, mode, &SimOpts::default());
            assert_eq!(o.completions().len(), 3, "{mode}: all mixed tenants finish");
        }
    }

    #[test]
    fn busy_runner_prices_a_busy_cluster() {
        use crate::tuner::{tune, TuneOpts};
        use crate::workloads::Workload;

        let cluster = ClusterSpec::mini();
        let target = Workload::MiniSortByKey.job();
        let background = background_jobs(2, 1_000_000, 16);

        let d = SparkConf::default();
        let mut busy = busy_runner(target.clone(), background.clone(), &cluster);
        let mut idle = busy_runner(target.clone(), Vec::new(), &cluster);
        let (b, i) = (busy(&d), idle(&d));
        assert!(b.is_finite() && i.is_finite());
        assert!(b >= i * 0.98, "contention must not speed the target up: busy {b:.2}s idle {i:.2}s");

        // The Fig-4 loop runs end-to-end against the busy cluster.
        let mut runner = busy_runner(target, background, &cluster);
        let out = tune(&mut runner, &TuneOpts::default());
        assert!(out.baseline.is_finite());
        assert!(out.best <= out.baseline);
        assert!(out.runs() <= 10);
    }

    #[test]
    fn tenancy_is_deterministic() {
        let cluster = ClusterSpec::mini();
        let jobs = workloads::multi_tenant(3, 1_000_000, 16);
        let conf = SparkConf::default();
        let a = run_tenancy(&jobs, &conf, &cluster, SchedulerMode::Fair, &SimOpts::default());
        let b = run_tenancy(&jobs, &conf, &cluster, SchedulerMode::Fair, &SimOpts::default());
        assert_eq!(a.completions(), b.completions());
        assert_eq!(a.batch.makespan, b.batch.makespan);
    }
}
