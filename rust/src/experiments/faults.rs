//! Fault-robustness experiment: the paper's methodology prices
//! configurations on a healthy testbed, but production clusters lose
//! executors and grow flaky nodes. This driver injects a deterministic
//! fault scenario (a black-hole node plus a small plan-wide transient
//! crash hazard) and shows the failure-policy knobs changing the
//! *ranking* of configurations:
//!
//! * a **fragile** configuration — Kryo plus `spark.task.maxFailures=1`
//!   — wins on the clean cluster but aborts on every fault draw (one
//!   commit on the flaky node exhausts its retry budget);
//! * the **defaults** survive on retries alone only if re-placements
//!   escape the flaky node;
//! * the **ensemble tuner** ([`FaultEnsembleRunner`] +
//!   [`TuneOpts::fault_ensemble`]) prices every decision-list step over
//!   k seeded fault draws and keeps the failure-policy steps that pay —
//!   node exclusion turns the black hole into a capacity loss and the
//!   incumbent finishes on every draw.

use crate::cluster::ClusterSpec;
use crate::conf::SparkConf;
use crate::engine::{prepare, run_planned, JobPlan};
use crate::report::Table;
use crate::sim::{FaultPlan, FlakyNode, SimOpts};
use crate::tuner::{
    ensemble_score, tune, FaultEnsembleOpts, FaultEnsembleRunner, ForkingRunner, Runner, TuneOpts,
    TuneOutcome,
};
use crate::workloads::Workload;
use std::sync::Arc;

/// Fixed scenario seed: the experiment is a deterministic function of
/// the workload and the fault plan.
pub const SEED: u64 = 0xFA11;

/// Fault draws per configuration (k of the ensemble).
pub const DRAWS: u32 = 5;

/// The injected scenario: node 1 is a black hole (every commit there
/// fails — the doomed attempt still consumes its full duration), and
/// every other attempt carries a 2 % transient crash hazard so the k
/// draws differ.
pub fn flaky_scenario() -> FaultPlan {
    FaultPlan {
        seed: SEED,
        task_crash_prob: 0.02,
        flaky: Some(FlakyNode { node: 1, crash_prob: 1.0 }),
        losses: Vec::new(),
    }
}

/// The configuration that wins clean and loses under failures: Kryo
/// buys real speed, but `spark.task.maxFailures=1` turns the first
/// crash into a job abort.
pub fn fragile_conf() -> SparkConf {
    SparkConf::default()
        .with("spark.serializer", "org.apache.spark.serializer.KryoSerializer")
        .with("spark.task.maxFailures", "1")
}

/// Everything the driver measured: clean makespans and the k fault-draw
/// makespans for the three contenders, plus the full tuning outcome.
#[derive(Clone, Debug)]
pub struct FaultsOutcome {
    pub clean_default: f64,
    pub clean_fragile: f64,
    pub clean_tuned: f64,
    pub faulted_default: Vec<f64>,
    pub faulted_fragile: Vec<f64>,
    pub faulted_tuned: Vec<f64>,
    /// The ensemble walk (its `best`/`baseline` are ensemble means).
    pub tuned: TuneOutcome,
}

impl FaultsOutcome {
    /// Aborted draws under `draws` (effective duration = ∞).
    pub fn aborted(draws: &[f64]) -> usize {
        draws.iter().filter(|d| d.is_infinite()).count()
    }
}

/// Price `conf` over the k seeded fault draws of [`flaky_scenario`].
/// Routed through [`FaultEnsembleRunner`] so the draw seeds are — by
/// construction, not by convention — the ones the tuner prices.
fn fault_draws(
    plan: &Arc<JobPlan>,
    conf: &SparkConf,
    cluster: &ClusterSpec,
    opts: &SimOpts,
) -> Vec<f64> {
    let mut r = FaultEnsembleRunner::new(
        ForkingRunner::new(Arc::clone(plan), cluster, opts.clone()),
        flaky_scenario(),
        FaultEnsembleOpts { draws: DRAWS, p95: false },
    );
    r.run(conf);
    r.last_draws().to_vec()
}

/// Run the whole comparison on `cluster` (mini-sort-by-key workload):
/// clean and faulted pricing for the defaults and the fragile conf,
/// then the ensemble decision-list walk and the same pricing for its
/// incumbent.
pub fn faults_experiment(cluster: &ClusterSpec) -> FaultsOutcome {
    let plan = prepare(&Workload::MiniSortByKey.job()).expect("mini workload plans cleanly");
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };

    let clean = |conf: &SparkConf| run_planned(&plan, conf, cluster, &opts).effective_duration();
    let clean_default = clean(&SparkConf::default());
    let clean_fragile = clean(&fragile_conf());
    let faulted_default = fault_draws(&plan, &SparkConf::default(), cluster, &opts);
    let faulted_fragile = fault_draws(&plan, &fragile_conf(), cluster, &opts);

    let ens = FaultEnsembleOpts { draws: DRAWS, p95: false };
    let mut runner = FaultEnsembleRunner::new(
        ForkingRunner::new(Arc::clone(&plan), cluster, opts.clone()),
        flaky_scenario(),
        ens,
    );
    let tuned = tune(&mut runner, &TuneOpts { fault_ensemble: Some(ens), ..TuneOpts::default() });

    let clean_tuned = clean(&tuned.best_conf);
    let faulted_tuned = fault_draws(&plan, &tuned.best_conf, cluster, &opts);
    FaultsOutcome {
        clean_default,
        clean_fragile,
        clean_tuned,
        faulted_default,
        faulted_fragile,
        faulted_tuned,
        tuned,
    }
}

/// Render the comparison as a markdown table: clean vs mean vs p95
/// makespans plus the abort count per configuration.
pub fn faults_table(o: &FaultsOutcome) -> Table {
    fn cell(v: f64) -> String {
        if v.is_finite() {
            format!("{v:.1}")
        } else {
            "aborted".into()
        }
    }
    fn row(label: &str, clean: f64, draws: &[f64]) -> Vec<String> {
        vec![
            label.into(),
            cell(clean),
            cell(ensemble_score(draws, false)),
            cell(ensemble_score(draws, true)),
            format!("{}/{}", FaultsOutcome::aborted(draws), draws.len()),
        ]
    }
    Table {
        title: format!(
            "Fault robustness — node 1 black-holed, {}% transient hazard, {} draws",
            flaky_scenario().task_crash_prob * 100.0,
            DRAWS
        ),
        header: vec![
            "configuration".into(),
            "clean (s)".into(),
            "mean faulted (s)".into(),
            "p95 faulted (s)".into(),
            "aborted draws".into(),
        ],
        rows: vec![
            row("defaults", o.clean_default, &o.faulted_default),
            row("fragile (kryo, maxFailures=1)", o.clean_fragile, &o.faulted_fragile),
            row("ensemble-tuned", o.clean_tuned, &o.faulted_tuned),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fragile_conf_wins_clean_but_aborts_on_every_draw() {
        let o = faults_experiment(&ClusterSpec::mini());
        assert!(
            o.clean_fragile < o.clean_default,
            "kryo must win clean: fragile {} vs default {}",
            o.clean_fragile,
            o.clean_default
        );
        // Node 1 holds block-placed generate tasks and every commit
        // there fails — one failure exhausts maxFailures=1 on any seed.
        assert_eq!(
            FaultsOutcome::aborted(&o.faulted_fragile),
            o.faulted_fragile.len(),
            "the fragile conf must abort on every draw: {:?}",
            o.faulted_fragile
        );
    }

    #[test]
    fn ensemble_tuner_finds_a_fault_robust_incumbent() {
        let o = faults_experiment(&ClusterSpec::mini());
        assert!(o.tuned.best.is_finite(), "ensemble walk must end on a finite incumbent");
        assert!(o.tuned.best <= o.tuned.baseline, "never worse than defaults by construction");
        assert_eq!(
            FaultsOutcome::aborted(&o.faulted_tuned),
            0,
            "the robust incumbent survives every draw: {:?}",
            o.faulted_tuned
        );
        // ... and beats the clean-cluster winner where it matters.
        assert!(
            ensemble_score(&o.faulted_tuned, false) < ensemble_score(&o.faulted_fragile, false),
            "robust {} !< fragile {} under injection",
            ensemble_score(&o.faulted_tuned, false),
            ensemble_score(&o.faulted_fragile, false)
        );
    }

    #[test]
    fn experiment_is_deterministic() {
        let a = faults_experiment(&ClusterSpec::mini());
        let b = faults_experiment(&ClusterSpec::mini());
        assert_eq!(a.clean_default.to_bits(), b.clean_default.to_bits());
        assert_eq!(a.tuned.best.to_bits(), b.tuned.best.to_bits());
        let bits = |v: &[f64]| v.iter().map(|d| d.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.faulted_default), bits(&b.faulted_default));
        assert_eq!(bits(&a.faulted_fragile), bits(&b.faulted_fragile));
        assert_eq!(bits(&a.faulted_tuned), bits(&b.faulted_tuned));
    }

    #[test]
    fn table_lists_three_confs_and_flags_aborts() {
        let o = faults_experiment(&ClusterSpec::mini());
        let md = faults_table(&o).to_markdown();
        assert!(md.contains("defaults"));
        assert!(md.contains("fragile (kryo, maxFailures=1)"));
        assert!(md.contains("ensemble-tuned"));
        assert!(md.contains("aborted"), "the fragile row must read as aborted");
    }
}
