//! The §5 case studies: apply the Fig-4 methodology end-to-end to
//! sort-by-key, the 500-column k-means instance, and aggregate-by-key,
//! and report the final configuration + speedup next to the paper's.

use crate::cluster::ClusterSpec;
use crate::conf::SparkConf;
use crate::engine::{prepare, run_planned};
use crate::report::Table;
use crate::sim::SimOpts;
use crate::tuner::{tune, TuneOpts, TuneOutcome};
use crate::workloads::Workload;

/// Paper-reported numbers for side-by-side reporting.
#[derive(Clone, Copy, Debug)]
pub struct PaperCase {
    pub default_secs: f64,
    pub best_secs: f64,
    pub improvement_pct: f64,
}

/// One case study result.
#[derive(Clone, Debug)]
pub struct CaseStudy {
    pub workload: Workload,
    pub threshold: f64,
    pub outcome: TuneOutcome,
    pub paper: PaperCase,
}

impl CaseStudy {
    pub fn improvement_pct(&self) -> f64 {
        100.0 * self.outcome.total_improvement()
    }
}

/// Tuning runner: one simulated run per candidate configuration (the
/// methodology is explicitly a *low-number-of-runs* protocol). The job
/// is planned once up front; every trial only re-prices it
/// (plan-once / price-many).
pub fn sim_runner<'a>(
    workload: Workload,
    cluster: &'a ClusterSpec,
) -> impl FnMut(&SparkConf) -> f64 + 'a {
    let plan = prepare(&workload.job()).expect("catalog workloads plan cleanly");
    move |conf: &SparkConf| {
        run_planned(&plan, conf, cluster, &SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None })
            .effective_duration()
    }
}

/// The three §5 case studies with the paper's thresholds.
pub fn case_studies(cluster: &ClusterSpec) -> Vec<CaseStudy> {
    let specs = [
        // (workload, threshold, paper numbers)
        (
            Workload::SortByKey1B,
            0.10,
            PaperCase { default_secs: 218.0, best_secs: 120.0, improvement_pct: 44.0 },
        ),
        (
            Workload::KMeans500D,
            0.05,
            PaperCase { default_secs: 654.0, best_secs: 54.0, improvement_pct: 91.7 },
        ),
        (
            Workload::AggregateByKey2B,
            0.05,
            PaperCase { default_secs: 77.5, best_secs: 61.2, improvement_pct: 21.0 },
        ),
    ];
    specs
        .into_iter()
        .map(|(w, threshold, paper)| {
            let mut runner = sim_runner(w, cluster);
            let outcome = tune(&mut runner, &TuneOpts { threshold, ..TuneOpts::default() });
            CaseStudy { workload: w, threshold, outcome, paper }
        })
        .collect()
}

/// Render the case studies as a markdown table.
pub fn case_table(cases: &[CaseStudy]) -> Table {
    let mut t = Table {
        title: "§5 case studies — methodology end-to-end (measured vs paper)".into(),
        header: vec![
            "case".into(),
            "threshold".into(),
            "default (s)".into(),
            "tuned (s)".into(),
            "improvement".into(),
            "paper".into(),
            "final configuration".into(),
        ],
        rows: Vec::new(),
    };
    for c in cases {
        let final_conf = c
            .outcome
            .final_settings()
            .iter()
            .map(|(k, v)| format!("{}={}", k.trim_start_matches("spark."), v))
            .collect::<Vec<_>>()
            .join(", ");
        t.rows.push(vec![
            c.workload.name().into(),
            format!("{:.0}%", c.threshold * 100.0),
            format!("{:.0}", c.outcome.baseline),
            format!("{:.0}", c.outcome.best),
            format!("{:.1}%", c.improvement_pct()),
            format!(
                "{:.0}→{:.0} ({:.0}%)",
                c.paper.default_secs, c.paper.best_secs, c.paper.improvement_pct
            ),
            if final_conf.is_empty() { "<defaults>".into() } else { final_conf },
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conf::ShuffleManagerKind;
    use crate::ser::SerKind;

    fn mn() -> ClusterSpec {
        ClusterSpec::marenostrum()
    }

    /// E5: sort-by-key case study — Kryo + a better manager must be kept
    /// and the total improvement must be substantial (paper: 44 %).
    #[test]
    fn case_study_sort_by_key() {
        let cluster = mn();
        let mut runner = sim_runner(Workload::SortByKey1B, &cluster);
        let out = tune(&mut runner, &TuneOpts { threshold: 0.10, ..TuneOpts::default() });
        assert_eq!(out.best_conf.serializer, SerKind::Kryo, "{:?}", out.trials);
        assert!(out.runs() <= 10);
        let improvement = out.total_improvement();
        assert!(
            improvement > 0.25,
            "sort-by-key improvement {improvement:.3} (baseline {:.0}s best {:.0}s, {:?})",
            out.baseline,
            out.best,
            out.final_settings()
        );
        // A non-default shuffle manager must have been chosen.
        assert_ne!(out.best_conf.shuffle_manager, ShuffleManagerKind::Sort);
    }

    /// E6: 500-column k-means — 0.1/0.7 must be kept; ≥50 % improvement
    /// (paper: 91.7 %; see EXPERIMENTS.md for the measured value).
    #[test]
    fn case_study_kmeans_500d() {
        let cluster = mn();
        let mut runner = sim_runner(Workload::KMeans500D, &cluster);
        let out = tune(&mut runner, &TuneOpts { threshold: 0.05, ..TuneOpts::default() });
        assert_eq!(out.best_conf.storage_memory_fraction, 0.7, "{:?}", out.final_settings());
        assert_eq!(out.best_conf.shuffle_memory_fraction, 0.1);
        let improvement = out.total_improvement();
        assert!(improvement > 0.5, "k-means improvement {improvement:.3}");
        // Kryo is NOT part of the final configuration (paper: "does not
        // include the KryoSerializer") — serializer impact is below the
        // 5% threshold on k-means.
        assert_eq!(out.best_conf.serializer, SerKind::Java, "{:?}", out.final_settings());
    }

    /// E7: aggregate-by-key — double-digit improvement at the 5% threshold
    /// (paper: ~21 %).
    #[test]
    fn case_study_aggregate_by_key() {
        let cluster = mn();
        let mut runner = sim_runner(Workload::AggregateByKey2B, &cluster);
        let out = tune(&mut runner, &TuneOpts { threshold: 0.05, ..TuneOpts::default() });
        let improvement = out.total_improvement();
        assert!(
            improvement > 0.08,
            "agg-by-key improvement {improvement:.3} (baseline {:.0}s best {:.0}s, {:?})",
            out.baseline,
            out.best,
            out.final_settings()
        );
        assert!(out.runs() <= 10);
    }

    #[test]
    fn case_table_renders() {
        // Structure-only check on the mini workload to stay fast.
        let cluster = ClusterSpec::mini();
        let mut runner = sim_runner(Workload::MiniSortByKey, &cluster);
        let out = tune(&mut runner, &TuneOpts::default());
        let case = CaseStudy {
            workload: Workload::MiniSortByKey,
            threshold: 0.0,
            outcome: out,
            paper: PaperCase { default_secs: 1.0, best_secs: 1.0, improvement_pct: 0.0 },
        };
        let md = case_table(&[case]).to_markdown();
        assert!(md.contains("mini-sort-by-key"));
        assert!(md.contains("improvement"));
    }
}
