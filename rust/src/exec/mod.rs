//! Executor model: Spark 1.5's **legacy memory manager** plus the GC
//! overhead model.
//!
//! Spark 1.5 (pre-unified-memory, i.e. exactly what the paper tuned)
//! splits each executor heap into static pools:
//!
//! ```text
//! heap × spark.storage.memoryFraction (0.6) × safetyFraction (0.9) → storage pool
//! heap × spark.shuffle.memoryFraction (0.2) × safetyFraction (0.8) → shuffle pool
//! the rest                                                         → unmanaged (user objects, netty, JVM)
//! ```
//!
//! The shuffle pool is divided evenly among concurrently running tasks
//! (`pool / cores`); a task whose aggregation/sort working set exceeds its
//! share **spills** to disk — unless even the spill path can't fit its
//! irreducible working memory (in-flight fetch buffers + merge-phase
//! buffers + a minimum sort batch), in which case the task — and the
//! paper's run — **crashes with OOM**. This is the mechanism behind the
//! paper's "values of 0.1 and 0.7 led to application crash" observations
//! for the shuffle-heavy benchmarks.
//!
//! The GC model charges a superlinear overhead in heap occupancy,
//! following the observation in the paper's ref [1] (Awan et al.) that GC
//! time grows faster than data size.

use crate::cluster::ClusterSpec;
use crate::conf::SparkConf;

/// Legacy-mode safety fractions (Spark 1.5 constants).
pub const STORAGE_SAFETY: f64 = 0.9;
pub const SHUFFLE_SAFETY: f64 = 0.8;

/// JVM object-graph expansion of deserialized records relative to payload
/// bytes. The benchmarks' records are `(String, String)` tuples (the
/// HiBench/bsc.spark generators build random *strings*): UTF-16 chars
/// double the bytes, plus two object headers and a tuple ≈ 2× payload.
pub const JVM_OBJECT_FACTOR: f64 = 2.0;

/// Expansion factor for *cached deserialized* RDDs (arrays dominate, so
/// lighter than per-record object graphs). At 1.5, the paper's
/// case-study-2 input (100 M × 500-dim points, 200 GB payload → 300 GB
/// cached) straddles the 0.6 (278 GB) vs 0.7 (324 GB) storage pools —
/// the geometry its 654 s → 54 s result requires.
pub const CACHE_DESER_FACTOR: f64 = 1.5;

/// Minimum in-memory batch a spilling **sorter** still needs, in bytes
/// (ExternalSorter page table + pointer array + growth headroom). A task
/// whose share is below this cannot make progress even by spilling —
/// Spark 1.5 surfaces it as an executor OOM, which is the paper's
/// observed crash at shuffle.memoryFraction = 0.1 (share ≈ 120 MB).
pub const MIN_SPILL_BATCH: u64 = 128 << 20;

/// Minimum batch for a spilling hash **aggregator** (AppendOnlyMap can
/// spill at much finer granularity than a sorter) — why aggregate-by-key
/// *survives* 0.1/0.7 (§5 case study 3) while the sorts crash.
pub const MIN_AGG_BATCH: u64 = 48 << 20;

/// OOM if the per-task share is below the irreducible working memory by
/// more than this slack factor.
pub const OOM_SLACK: f64 = 1.0;

/// Result of sizing a task's shuffle working set against its memory share.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpillPlan {
    /// Fits in memory: no spill.
    InMemory,
    /// Spills: `spill_bytes` of (serialized-form) data go to disk and come
    /// back during the merge, in `files` spill files.
    Spill { spill_bytes: u64, files: u32 },
    /// Irreducible working memory exceeds the share → task-level OOM,
    /// which Spark 1.5 surfaces as an application crash after retries.
    Oom { need: u64, share: u64 },
}

/// Error carried up through job execution when a stage OOMs.
#[derive(Debug, Clone, PartialEq)]
pub struct OomError {
    pub stage: String,
    pub need: u64,
    pub share: u64,
    pub pool: u64,
    pub concurrent: u32,
}

impl std::fmt::Display for OomError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "OOM: stage {} task working set needs {} B but per-task share is {} B \
             (shuffle pool {} B / {} concurrent tasks)",
            self.stage, self.need, self.share, self.pool, self.concurrent
        )
    }
}

impl std::error::Error for OomError {}

/// The per-executor memory pools implied by a configuration.
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    /// Block-manager cache pool per executor, bytes.
    pub storage_pool: u64,
    /// Shuffle (execution) pool per executor, bytes.
    pub shuffle_pool: u64,
    /// Executor heap, bytes.
    pub heap: u64,
    /// Concurrent tasks per executor (= cores).
    pub concurrent_tasks: u32,
}

impl MemoryModel {
    pub fn new(conf: &SparkConf, cluster: &ClusterSpec) -> MemoryModel {
        let heap = cluster.heap_per_node;
        MemoryModel {
            storage_pool: (heap as f64 * conf.storage_memory_fraction * STORAGE_SAFETY) as u64,
            shuffle_pool: (heap as f64 * conf.shuffle_memory_fraction * SHUFFLE_SAFETY) as u64,
            heap,
            concurrent_tasks: cluster.cores_per_node,
        }
    }

    /// Per-task share of the shuffle pool (even split across running
    /// tasks, as in `ShuffleMemoryManager`).
    pub fn per_task_share(&self) -> u64 {
        self.shuffle_pool / self.concurrent_tasks.max(1) as u64
    }

    /// Cluster-wide storage pool (× nodes is the caller's job; this is per
    /// executor).
    pub fn storage_pool(&self) -> u64 {
        self.storage_pool
    }

    /// Decide the spill plan for a task whose in-memory working set is
    /// `working_bytes` (already including [`JVM_OBJECT_FACTOR`]), with
    /// `irreducible_bytes` of *on-heap* fixed overhead (on-heap fetch
    /// buffers when `preferDirectBufs=false`; 0 when they're off-heap)
    /// and `min_batch` of irreducible spill-batch memory
    /// ([`MIN_SPILL_BATCH`] for sorters, [`MIN_AGG_BATCH`] for
    /// aggregators).
    pub fn plan_task(
        &self,
        working_bytes: u64,
        irreducible_bytes: u64,
        min_batch: u64,
        spill_allowed: bool,
    ) -> SpillPlan {
        let share = self.per_task_share();
        if working_bytes + irreducible_bytes <= share {
            return SpillPlan::InMemory;
        }
        let floor = irreducible_bytes + min_batch.min(working_bytes);
        if !spill_allowed || (floor as f64) > share as f64 * OOM_SLACK {
            return SpillPlan::Oom { need: floor, share };
        }
        // Everything beyond the in-memory batch cycles through disk once.
        let batch = share - irreducible_bytes;
        let spill_bytes = working_bytes.saturating_sub(batch).max(1);
        let files = (working_bytes as f64 / batch as f64).ceil() as u32 - 1;
        SpillPlan::Spill { spill_bytes, files: files.max(1) }
    }

    /// GC overhead multiplier on CPU time given executor heap occupancy
    /// (live bytes / heap). Superlinear per [1]: minor-GC base plus a
    /// cubic blow-up as occupancy approaches 1.
    pub fn gc_overhead(&self, live_bytes: u64) -> f64 {
        let occ = (live_bytes as f64 / self.heap as f64).clamp(0.0, 1.5);
        0.02 + 0.30 * occ * occ * occ
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm(shuffle_frac: f64, storage_frac: f64) -> MemoryModel {
        let conf = SparkConf::default()
            .with("spark.shuffle.memoryFraction", &format!("{shuffle_frac}"))
            .with("spark.storage.memoryFraction", &format!("{storage_frac}"));
        MemoryModel::new(&conf, &ClusterSpec::marenostrum())
    }

    #[test]
    fn default_pools_match_spark_15_constants() {
        let m = mm(0.2, 0.6);
        let heap = 24u64 << 30;
        assert_eq!(m.heap, heap);
        assert_eq!(m.storage_pool, (heap as f64 * 0.6 * 0.9) as u64);
        assert_eq!(m.shuffle_pool, (heap as f64 * 0.2 * 0.8) as u64);
        assert_eq!(m.concurrent_tasks, 16);
        // per-task share ≈ 245 MB
        let share = m.per_task_share();
        assert!(share > 240 << 20 && share < 250 << 20, "{share}");
    }

    #[test]
    fn small_working_sets_stay_in_memory() {
        let m = mm(0.2, 0.6);
        assert_eq!(m.plan_task(100 << 20, 0, MIN_SPILL_BATCH, true), SpillPlan::InMemory);
    }

    #[test]
    fn oversized_working_sets_spill() {
        let m = mm(0.2, 0.6);
        match m.plan_task(1 << 30, 0, MIN_SPILL_BATCH, true) {
            SpillPlan::Spill { spill_bytes, files } => {
                assert!(spill_bytes > 700 << 20, "{spill_bytes}");
                assert!(files >= 4, "{files}");
            }
            other => panic!("expected spill, got {other:?}"),
        }
    }

    #[test]
    fn starved_share_ooms_for_sorters_not_aggregators() {
        // 0.1/0.7 on MareNostrum: share = 24G×0.1×0.8/16 ≈ 120 MB. A
        // sorter needs a 128 MB minimum batch → OOM (the paper's crash);
        // an aggregator (48 MB min batch) spills and survives — why
        // aggregate-by-key's best config in §5 IS 0.1/0.7.
        let m = mm(0.1, 0.7);
        let share = m.per_task_share();
        assert!(share < 125 << 20);
        match m.plan_task(400 << 20, 0, MIN_SPILL_BATCH, true) {
            SpillPlan::Oom { need, share: s } => assert!(need > s),
            other => panic!("expected OOM, got {other:?}"),
        }
        assert!(matches!(
            m.plan_task(400 << 20, 0, MIN_AGG_BATCH, true),
            SpillPlan::Spill { .. }
        ));
        // Default 0.2 with the same sorter task: spills but survives.
        let m = mm(0.2, 0.6);
        assert!(matches!(
            m.plan_task(400 << 20, 0, MIN_SPILL_BATCH, true),
            SpillPlan::Spill { .. }
        ));
    }

    #[test]
    fn spill_disabled_turns_pressure_into_oom() {
        let m = mm(0.2, 0.6);
        assert!(matches!(m.plan_task(1 << 30, 0, MIN_SPILL_BATCH, false), SpillPlan::Oom { .. }));
        assert!(matches!(m.plan_task(1 << 20, 0, MIN_SPILL_BATCH, false), SpillPlan::InMemory));
    }

    #[test]
    fn gc_overhead_superlinear() {
        let m = mm(0.2, 0.6);
        let low = m.gc_overhead((0.2 * m.heap as f64) as u64);
        let mid = m.gc_overhead((0.6 * m.heap as f64) as u64);
        let high = m.gc_overhead((0.9 * m.heap as f64) as u64);
        assert!(low < 0.03, "{low}");
        assert!(mid > low && high > mid);
        // Superlinearity: the 0.6→0.9 increment dwarfs 0.2→0.6 per unit.
        assert!((high - mid) / 0.3 > (mid - low) / 0.4);
        assert!(high < 0.35, "{high}");
    }

    #[test]
    fn shares_scale_with_fraction() {
        let a = mm(0.4, 0.4).per_task_share() as f64;
        let b = mm(0.2, 0.6).per_task_share() as f64;
        assert!((a / b - 2.0).abs() < 1e-6, "{a} vs {b}");
    }
}
