//! Integration tests for the tuning-as-a-service core: the acceptance
//! criteria of the service layer, pinned end to end.
//!
//! * **Parity** — `serve`-mediated outcomes are bit-identical to a
//!   direct `tuner::tune` call, for any worker count and any cache
//!   warmth (cold run vs fully-warm rerun).
//! * **Dedup** — overlapping sessions simulate strictly fewer trials
//!   than they request.
//! * **Fingerprint goldens** — set-order invariance and sensitivity of
//!   the trial fingerprint across every component of the trial key.
//! * **Evidence transfer** — job profiles and the kNN warm start,
//!   end to end through the public service API.

use sparktune::cluster::ClusterSpec;
use sparktune::conf::SparkConf;
use sparktune::engine::{prepare, run};
use sparktune::service::{
    fingerprint_trial, outcomes_identical, JobProfile, ServiceOpts, SessionRequest, TuningService,
};
use sparktune::sim::SimOpts;
use sparktune::tuner::{tune, TuneOpts};
use sparktune::workloads::{self, Workload};

fn sim() -> SimOpts {
    SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None }
}

fn request(name: &str, w: Workload, tune: TuneOpts) -> SessionRequest {
    SessionRequest { name: name.into(), job: w.job(), tune, sim: sim() }
}

#[test]
fn served_outcome_is_bit_identical_to_direct_tune() {
    let cluster = ClusterSpec::mini();
    let topts = TuneOpts::default();

    // Ground truth: the tuner driving the simulator directly.
    let job = Workload::MiniSortByKey.job();
    let mut direct_runner =
        |conf: &SparkConf| run(&job, conf, &cluster, &sim()).effective_duration();
    let direct = tune(&mut direct_runner, &topts);

    for workers in [1usize, 4, 8] {
        let svc = TuningService::new(
            cluster.clone(),
            ServiceOpts { workers, shards: 4, capacity: 1024, ..ServiceOpts::default() },
        );
        let req = request("solo", Workload::MiniSortByKey, topts.clone());
        // Cold pass.
        let cold = svc.serve(std::slice::from_ref(&req)).remove(0).outcome;
        assert!(
            outcomes_identical(&cold, &direct),
            "cold serve (workers={workers}) diverged from direct tune"
        );
        // Fully-warm rerun on the same service.
        let warm = svc.serve(std::slice::from_ref(&req)).remove(0).outcome;
        assert!(
            outcomes_identical(&warm, &direct),
            "warm serve (workers={workers}) diverged from direct tune"
        );
        // The warm pass must not have simulated anything new.
        let s = svc.stats();
        assert_eq!(s.trials_simulated, direct.runs() as u64, "workers={workers}");
        assert_eq!(s.trials_requested, 2 * direct.runs() as u64, "workers={workers}");
    }
}

#[test]
fn overlapping_sessions_simulate_strictly_fewer_trials() {
    let cluster = ClusterSpec::mini();
    let topts = TuneOpts { short_version: true, ..TuneOpts::default() };
    // 5 tenants tuning the same app: 5× the requests, 1× the simulations.
    let reqs: Vec<SessionRequest> = (0..5)
        .map(|t| request(&format!("tenant{t}"), Workload::MiniSortByKey, topts.clone()))
        .collect();
    let svc =
        TuningService::new(cluster.clone(), ServiceOpts { workers: 4, shards: 4, capacity: 1024, ..ServiceOpts::default() });
    let out = svc.serve(&reqs);
    let s = svc.stats();
    assert_eq!(s.sessions, 5);
    assert_eq!(
        s.trials_simulated,
        out[0].outcome.runs() as u64,
        "identical sessions must collapse to one simulation per trial"
    );
    assert_eq!(s.trials_requested, 5 * out[0].outcome.runs() as u64);
    assert!(s.hit_rate() > 0.0);
    for o in &out[1..] {
        assert!(outcomes_identical(&out[0].outcome, &o.outcome), "{} diverged", o.name);
    }
}

#[test]
fn golden_fingerprint_stability() {
    // Same effective trial key through different construction orders →
    // the same fingerprint, run after run.
    let cluster = ClusterSpec::mini();
    let job = Workload::MiniSortByKey.job();
    let a = SparkConf::default()
        .with("spark.serializer", "kryo")
        .with("spark.shuffle.file.buffer", "96k")
        .with("spark.locality.wait", "300ms");
    let b = SparkConf::default()
        .with("spark.locality.wait", "0.3s")
        .with("spark.serializer", "org.apache.spark.serializer.KryoSerializer")
        .with("spark.shuffle.file.buffer", "96k");
    let fa = fingerprint_trial(&job, &a, &cluster, &sim());
    let fb = fingerprint_trial(&job, &b, &cluster, &sim());
    assert_eq!(fa, fb, "set order and value spellings must canonicalize away");
    assert_eq!(fa, fingerprint_trial(&job, &a, &cluster, &sim()), "stable across calls");

    // Any effective change flips it.
    let c = a.clone().with("spark.shuffle.file.buffer", "64k");
    assert_ne!(fa, fingerprint_trial(&job, &c, &cluster, &sim()));
    let mut other_sim = sim();
    other_sim.seed += 1;
    assert_ne!(fa, fingerprint_trial(&job, &a, &cluster, &other_sim));
    let other_job = Workload::KMeans100M.job();
    assert_ne!(fa, fingerprint_trial(&other_job, &a, &cluster, &sim()));
    let mut other_cluster = cluster.clone();
    other_cluster.disk_bw *= 2.0;
    assert_ne!(fa, fingerprint_trial(&job, &a, &other_cluster, &sim()));
}

#[test]
fn service_handles_crashing_configurations() {
    // The 0.1/0.7 OOM regime returns INFINITY through the cache exactly
    // like it does directly; crashes memoize as crashes.
    let cluster = ClusterSpec::marenostrum();
    let svc =
        TuningService::new(cluster.clone(), ServiceOpts { workers: 2, shards: 2, capacity: 64, ..ServiceOpts::default() });
    let job = Workload::SortByKey1B.job();
    let crashing = SparkConf::default()
        .with("spark.shuffle.memoryFraction", "0.1")
        .with("spark.storage.memoryFraction", "0.7");
    let first = svc.evaluate(&job, &crashing, &sim());
    let second = svc.evaluate(&job, &crashing, &sim());
    assert!(first.is_infinite(), "0.1/0.7 must crash sort-by-key");
    assert_eq!(first.to_bits(), second.to_bits());
    let s = svc.stats();
    assert_eq!((s.trials_requested, s.trials_simulated), (2, 1));
}

#[test]
fn tiny_cache_still_serves_correctly() {
    // With capacity 1 the cache thrashes, but purity keeps results
    // exact — memoization is an optimization, never a semantic.
    let cluster = ClusterSpec::mini();
    let topts = TuneOpts { short_version: true, ..TuneOpts::default() };
    let svc =
        TuningService::new(cluster.clone(), ServiceOpts { workers: 2, shards: 1, capacity: 1, ..ServiceOpts::default() });
    let req = request("thrash", Workload::MiniSortByKey, topts.clone());
    let served = svc.serve(std::slice::from_ref(&req)).remove(0).outcome;
    let job = Workload::MiniSortByKey.job();
    let mut direct_runner =
        |conf: &SparkConf| run(&job, conf, &cluster, &sim()).effective_duration();
    let direct = tune(&mut direct_runner, &topts);
    assert!(outcomes_identical(&served, &direct));
    assert!(svc.stats().cache.evictions > 0, "capacity 1 must evict");
}

#[test]
fn job_profiles_cluster_workload_families() {
    // The public-API view of the profile goldens: same family at a new
    // scale stays close; a different family is far; serialization is an
    // exact round trip (the future persisted-index format).
    let cluster = ClusterSpec::mini();
    let profile = |job: &sparktune::engine::Job| {
        JobProfile::of(&prepare(job).unwrap(), &cluster, &sim())
    };
    let sbk = profile(&workloads::sort_by_key(2_000_000, 16));
    let sbk_scaled = profile(&workloads::sort_by_key(2_100_000, 16));
    let kmeans = profile(&workloads::kmeans(100_000, 20, 4, 2, 16));
    assert!(sbk.distance(&sbk_scaled) < 0.05, "{}", sbk.distance(&sbk_scaled));
    assert!(sbk.distance(&kmeans) > 0.25, "{}", sbk.distance(&kmeans));
    let round = JobProfile::deserialize(&sbk.serialize()).expect("round trip");
    assert_eq!(round, sbk);
}

#[test]
fn warm_started_service_transfers_across_scales_end_to_end() {
    // Train on one scale, admit a 1%-larger workload of the same
    // family: the service must warm-start it, reach the cold session's
    // final configuration quality, and spend strictly fewer runs.
    let cluster = ClusterSpec::mini();
    let svc = TuningService::new(
        cluster.clone(),
        ServiceOpts { warm_start: true, ..ServiceOpts::default() },
    );
    let topts = TuneOpts { short_version: true, ..TuneOpts::default() };
    let request = |name: &str, records: u64| SessionRequest {
        name: name.into(),
        job: workloads::sort_by_key(records, 16),
        tune: topts.clone(),
        sim: sim(),
    };
    svc.serve(&[request("train", 2_000_000)]);
    let warm = svc.serve(&[request("apply", 2_020_000)]).remove(0);
    assert_eq!(warm.warm_from.as_deref(), Some("train"));

    // Cold control: the identical held-out workload tuned directly.
    let held_out = workloads::sort_by_key(2_020_000, 16);
    let mut cold_runner =
        |conf: &SparkConf| run(&held_out, conf, &cluster, &sim()).effective_duration();
    let cold = tune(&mut cold_runner, &topts);
    assert!(
        warm.outcome.runs() < cold.runs(),
        "warm {} runs vs cold {}",
        warm.outcome.runs(),
        cold.runs()
    );
    assert!(warm.outcome.best.is_finite());
    assert!(
        warm.outcome.best <= cold.best,
        "warm {} vs cold {}",
        warm.outcome.best,
        cold.best
    );
}
