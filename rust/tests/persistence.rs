//! Integration tests for the durable service layer: the acceptance
//! criteria of `docs/FORMATS.md`, pinned end to end.
//!
//! * **Worked-example golden** — the exact `sparktune.snapshot.v1`
//!   cache payload printed in `docs/FORMATS.md` §"Worked example" is
//!   what `encode_cache` emits for that state, byte for byte, and it
//!   decodes back bit-exactly.
//! * **Reject, don't guess** — truncated, corrupt, version-skewed, and
//!   geometry-mismatched snapshots are refused with a reason, at the
//!   file level and at the directory level.
//! * **Restart equivalence** — a warm-restarted service produces
//!   outcomes bit-identical to the never-restarted twin, across worker
//!   counts, and serves its first restored pass entirely from memo.
//! * **Never partially applied** — one corrupt shard file rejects a
//!   whole router restore and leaves every shard's live state
//!   untouched.
//! * **Shard equivalence** — a 4-shard router, a 1-shard router, and a
//!   single `TuningService` serve the same batch bit-identically.

use std::path::PathBuf;

use sparktune::cluster::ClusterSpec;
use sparktune::service::persist;
use sparktune::service::{
    outcomes_identical, ServiceOpts, SessionOutcome, SessionRequest, ShardedCache, ShardedRouter,
    TuningService,
};
use sparktune::sim::SimOpts;
use sparktune::tuner::TuneOpts;
use sparktune::workloads;

fn sim() -> SimOpts {
    SimOpts { jitter: 0.04, seed: 0x51A7, straggler: None }
}

/// A small cross-family batch: two sort-by-key scales (close profiles,
/// so warm-start has something to transfer) plus a k-means outlier.
fn batch() -> Vec<SessionRequest> {
    let topts = TuneOpts { short_version: true, ..TuneOpts::default() };
    vec![
        SessionRequest {
            name: "tenant0/sbk".into(),
            job: workloads::sort_by_key(2_000_000, 16),
            tune: topts.clone(),
            sim: sim(),
        },
        SessionRequest {
            name: "tenant1/sbk-scaled".into(),
            job: workloads::sort_by_key(2_020_000, 16),
            tune: topts.clone(),
            sim: sim(),
        },
        SessionRequest {
            name: "tenant2/kmeans".into(),
            job: workloads::kmeans(100_000, 20, 4, 2, 16),
            tune: topts,
            sim: sim(),
        },
    ]
}

fn opts(workers: usize) -> ServiceOpts {
    ServiceOpts { workers, shards: 4, capacity: 4096, warm_start: true, ..ServiceOpts::default() }
}

/// Fresh temp dir path (not yet created) unique to this test + process.
fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sparktune-persist-{tag}-{}", std::process::id()));
    if dir.exists() {
        std::fs::remove_dir_all(&dir).expect("clear stale temp dir");
    }
    dir
}

fn assert_batches_identical(a: &[SessionOutcome], b: &[SessionOutcome], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: batch lengths differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.session, y.session, "{what}: session ids diverged");
        assert_eq!(x.name, y.name, "{what}: session names diverged");
        assert_eq!(x.warm_from, y.warm_from, "{what}: warm-start choices diverged ({})", x.name);
        assert!(outcomes_identical(&x.outcome, &y.outcome), "{what}: {} diverged", x.name);
    }
}

// ---------------------------------------------------------------------------
// Worked-example golden (docs/FORMATS.md §Worked example)
// ---------------------------------------------------------------------------

/// The exact payload (everything before the `checksum=` line) that
/// `docs/FORMATS.md` walks through byte by byte. Keep the two in sync:
/// the doc is normative, this test is its executable witness.
const WORKED_EXAMPLE_PAYLOAD: &str = "\
sparktune.snapshot.v1;kind=cache;shards=1;cap=4
shard=0;tick=2;inflation=0000000000000000
entry=00000000000000000000000000000002;value=401d000000000000;cost=0000000000000000;prio=0000000000000000;qtick=2
entry=00000000000000000000000000000001;value=4045400000000000;cost=3ff8000000000000;prio=3ff8000000000000;qtick=1
";

/// Rebuild the worked example's cache state through the public API.
fn worked_example_cache() -> ShardedCache<f64> {
    use sparktune::service::Fingerprint;
    let cache: ShardedCache<f64> = ShardedCache::new(1, 4);
    // Trial 1: 42.5 s effective duration, 1.5 s to compute.
    cache.insert_costed(Fingerprint(1), 42.5, 1.5);
    // Trial 2: 7.25 s effective duration, free to compute (cost 0), so
    // it queues *ahead* of trial 1 in eviction order despite being
    // younger — the GreedyDual priority, not insertion order, sorts
    // the entry lines.
    cache.insert_costed(Fingerprint(2), 7.25, 0.0);
    cache
}

#[test]
fn formats_md_worked_example_is_what_we_emit() {
    let encoded = persist::encode_cache(&worked_example_cache());
    let payload = persist::unseal(&encoded).expect("own snapshot must unseal");
    assert_eq!(
        payload, WORKED_EXAMPLE_PAYLOAD,
        "docs/FORMATS.md worked example drifted from encode_cache"
    );
    // The final line is the checksum over exactly that payload.
    assert!(encoded.ends_with('\n'));
    let last = encoded.lines().last().unwrap();
    assert!(last.starts_with("checksum="), "last line is {last}");
    assert_eq!(last.len(), "checksum=".len() + 32, "Fp128 prints as 32 hex digits");
}

#[test]
fn formats_md_worked_example_round_trips_bit_exactly() {
    let cache = worked_example_cache();
    let encoded = persist::encode_cache(&cache);
    let decoded = persist::decode_cache(&encoded, 1, 4).expect("own snapshot must decode");
    let restored: ShardedCache<f64> = ShardedCache::new(1, 4);
    restored.restore_shards(decoded).expect("decoded exports must restore");
    assert_eq!(
        persist::encode_cache(&restored),
        encoded,
        "decode→restore→encode must be the identity"
    );
    // Canonical: the same state always serializes to the same bytes.
    assert_eq!(persist::encode_cache(&cache), encoded);
}

// ---------------------------------------------------------------------------
// File-level rejection goldens
// ---------------------------------------------------------------------------

#[test]
fn snapshot_rejections_name_their_reason() {
    let sealed = persist::encode_cache(&worked_example_cache());

    // Version skew: a future (or foreign) version is refused, never
    // half-parsed.
    let skewed = persist::seal(
        WORKED_EXAMPLE_PAYLOAD.replace("sparktune.snapshot.v1", "sparktune.snapshot.v9"),
    );
    let err = persist::decode_cache(&skewed, 1, 4).unwrap_err();
    assert!(err.contains("unsupported snapshot version"), "{err}");

    // Kind mismatch: a sealed fork ledger is not a cache snapshot.
    let fork = persist::encode_fork(&persist::ForkLedger {
        budget: 1024,
        tick: 0,
        inflation: 0.0,
        evictions: 0,
        crashes: Vec::new(),
    });
    let err = persist::decode_cache(&fork, 1, 4).unwrap_err();
    assert!(err.contains("kind"), "{err}");

    // Truncation before the checksum line: the framing itself fails.
    let no_checksum = sealed.lines().next().map(|l| format!("{l}\n")).unwrap();
    let err = persist::decode_cache(&no_checksum, 1, 4).unwrap_err();
    assert!(err.contains("missing checksum line"), "{err}");

    // Truncation that keeps the checksum line: the checksum catches it.
    let mut lines: Vec<&str> = sealed.lines().collect();
    let checksum = lines.pop().unwrap();
    lines.remove(lines.len() - 1); // drop the last entry line
    let truncated = format!("{}\n{checksum}\n", lines.join("\n"));
    let err = persist::decode_cache(&truncated, 1, 4).unwrap_err();
    assert!(err.contains("checksum mismatch"), "{err}");

    // A single flipped byte in the payload: ditto.
    let flipped = sealed.replacen("tick=2", "tick=3", 1);
    let err = persist::decode_cache(&flipped, 1, 4).unwrap_err();
    assert!(err.contains("checksum mismatch"), "{err}");

    // Bytes appended after the seal: the checksum is no longer last.
    let appended = format!("{sealed}entry=trailing-garbage\n");
    let err = persist::decode_cache(&appended, 1, 4).unwrap_err();
    assert!(err.contains("missing checksum line"), "{err}");

    // Geometry mismatch: a valid snapshot for the wrong cache shape.
    let err = persist::decode_cache(&sealed, 2, 4).unwrap_err();
    assert!(err.contains("cache geometry mismatch"), "{err}");
}

// ---------------------------------------------------------------------------
// Restart equivalence
// ---------------------------------------------------------------------------

#[test]
fn restored_service_is_bit_identical_to_never_restarted_twin() {
    let cluster = ClusterSpec::mini();
    let reqs = batch();
    let dir = temp_dir("restart");
    let mut reference: Option<Vec<SessionOutcome>> = None;

    for workers in [1usize, 4] {
        // The never-restarted service: cold pass, then a warm pass
        // (which exercises kNN warm-start against the pass-1 evidence),
        // then a snapshot of everything it knows.
        let live = TuningService::new(cluster.clone(), opts(workers));
        live.serve(&reqs);
        live.serve(&reqs);
        live.snapshot_to(&dir).expect("snapshot");

        // The restarted twin: same geometry, state restored from disk.
        let twin = TuningService::new(cluster.clone(), opts(workers));
        twin.restore_from(&dir).expect("restore");

        // Both serve the batch once more: bit-identical outcomes and
        // warm-start choices…
        let live_pass = live.serve(&reqs);
        let twin_pass = twin.serve(&reqs);
        assert_batches_identical(&live_pass, &twin_pass, &format!("workers={workers}"));

        // …and the twin served entirely from restored evidence: zero
        // fresh simulations, every session warm-started.
        let s = twin.stats();
        assert_eq!(s.trials_simulated, 0, "restored twin re-simulated (workers={workers})");
        assert!(s.trials_requested > 0);
        for o in &twin_pass {
            assert!(o.warm_from.is_some(), "{} did not warm-start after restore", o.name);
        }

        // Outcomes are also invariant across worker counts.
        match &reference {
            None => reference = Some(twin_pass),
            Some(r) => assert_batches_identical(r, &twin_pass, "across worker counts"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Never partially applied
// ---------------------------------------------------------------------------

#[test]
fn corrupt_shard_rejects_whole_router_restore_and_leaves_state_untouched() {
    let cluster = ClusterSpec::mini();
    let reqs = batch();
    let dir = temp_dir("staged");

    let router = ShardedRouter::new(cluster.clone(), 4, opts(2));
    router.serve(&reqs); // cold pass: builds the evidence
    let before = router.serve(&reqs); // steady state: warm, fully memoized
    router.snapshot_to(&dir).expect("snapshot");

    // Corrupt exactly one shard's cache file (bytes after the seal).
    let victim = dir.join("shard-0002").join("cache.snap");
    let mut text = std::fs::read_to_string(&victim).expect("read shard cache");
    text.push_str("entry=trailing-garbage\n");
    std::fs::write(&victim, text).expect("corrupt shard cache");

    // The whole restore is rejected — including the three shards whose
    // files are pristine…
    let err = router.restore_from(&dir).expect_err("corrupt shard must reject");
    let msg = err.to_string();
    assert!(msg.contains("snapshot rejected"), "{msg}");
    assert!(msg.contains("cache.snap"), "{msg}");

    // …and the live state is untouched: the batch re-serves entirely
    // from the router's own memo, bit-identically.
    let simulated_before = router.stats().trials_simulated;
    let after = router.serve(&reqs);
    assert_batches_identical(&before, &after, "post-rejection state");
    assert_eq!(
        router.stats().trials_simulated,
        simulated_before,
        "rejected restore must not cost the router its memo"
    );

    // A fresh router refuses the same directory without picking up any
    // partial state: it still cold-serves afterwards.
    let fresh = ShardedRouter::new(cluster.clone(), 4, opts(2));
    fresh.restore_from(&dir).expect_err("corrupt shard must reject");
    assert_eq!(fresh.cached_trials(), 0, "rejected restore must not leak entries");

    // Shard-count skew is a manifest-level rejection.
    let reshard = ShardedRouter::new(cluster, 2, opts(2));
    let err = reshard.restore_from(&dir).expect_err("re-shard must reject");
    assert!(err.to_string().contains("shards"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn quarantine_renames_rejected_state_dirs() {
    let dir = temp_dir("quarantine");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.snap"), "junk\n").unwrap();

    let q0 = persist::quarantine_dir(&dir).expect("quarantine");
    assert!(!dir.exists());
    let expected = format!("sparktune-persist-quarantine-{}.corrupt-0", std::process::id());
    assert!(q0.ends_with(&expected), "{}", q0.display());
    assert!(q0.join("manifest.snap").exists(), "rejected bytes are preserved for forensics");

    // A second rejection of the same path picks the next free slot.
    std::fs::create_dir_all(&dir).unwrap();
    let q1 = persist::quarantine_dir(&dir).expect("quarantine again");
    assert!(q1.to_string_lossy().ends_with(".corrupt-1"), "{}", q1.display());

    std::fs::remove_dir_all(&q0).ok();
    std::fs::remove_dir_all(&q1).ok();
}

// ---------------------------------------------------------------------------
// Shard equivalence
// ---------------------------------------------------------------------------

#[test]
fn four_shards_one_shard_and_a_single_service_agree_bitwise() {
    let cluster = ClusterSpec::mini();
    let reqs = batch();

    let single = TuningService::new(cluster.clone(), opts(2));
    let one = ShardedRouter::new(cluster.clone(), 1, opts(2));
    let four = ShardedRouter::new(cluster.clone(), 4, opts(2));

    // Two passes each: the second exercises cross-shard warm-start
    // against the first pass's recorded evidence.
    for pass in 0..2 {
        let a = single.serve(&reqs);
        let b = one.serve(&reqs);
        let c = four.serve(&reqs);
        assert_batches_identical(&a, &b, &format!("pass {pass}: single vs 1-shard"));
        assert_batches_identical(&a, &c, &format!("pass {pass}: single vs 4-shard"));
    }

    // The 4-shard router genuinely spread the work: more than one shard
    // holds cached trials.
    let populated = four.shards().iter().filter(|s| s.cached_trials() > 0).count();
    assert!(populated > 1, "profile-hash routing left {populated} shard(s) populated");

    // And the evidence totals agree with the single service.
    assert_eq!(four.profiled_sessions(), single.profiled_sessions());
}

// ---------------------------------------------------------------------------
// Restart equivalence, sharded: snapshot/restore through the router
// ---------------------------------------------------------------------------

#[test]
fn restored_router_serves_entirely_from_snapshot() {
    let cluster = ClusterSpec::mini();
    let reqs = batch();
    let dir = temp_dir("router-restart");

    let live = ShardedRouter::new(cluster.clone(), 4, opts(2));
    live.serve(&reqs);
    live.serve(&reqs);
    live.snapshot_to(&dir).expect("snapshot");

    let twin = ShardedRouter::new(cluster.clone(), 4, opts(2));
    twin.restore_from(&dir).expect("restore");

    let live_pass = live.serve(&reqs);
    let twin_pass = twin.serve(&reqs);
    assert_batches_identical(&live_pass, &twin_pass, "router restart");
    assert_eq!(twin.stats().trials_simulated, 0, "restored router re-simulated");

    std::fs::remove_dir_all(&dir).ok();
}
