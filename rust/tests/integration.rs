//! Cross-module integration and property tests (the `testkit::forall`
//! harness stands in for proptest on this offline image).
//!
//! Invariant families:
//! * substrates — codec/serializer round-trips over arbitrary inputs;
//! * simulator — work conservation, core-capacity limits, determinism;
//! * engine — resource monotonicity, crash monotonicity in memory
//!   fractions, stage accounting;
//! * tuner — never worse than baseline, threshold discipline, run budget;
//! * configuration — parse/diff round-trips over the whole grid.

use sparktune::cluster::ClusterSpec;
use sparktune::codec::{compress_framed, decompress_framed, CodecKind};
use sparktune::conf::SparkConf;
use sparktune::engine::{run, Dataset, Job, Op};
use sparktune::ser::{Record, SerKind};
use sparktune::sim::{run_stage, Phase, SimOpts, TaskSpec};
use sparktune::testkit::forall;
use sparktune::tuner::baselines::{grid_conf, grid_size};
use sparktune::tuner::{tune, TuneOpts};
use sparktune::workloads::{self, Workload};

// ---------- substrates ----------

#[test]
fn prop_codec_round_trip_arbitrary() {
    forall("codec round-trip", 0xC0DE, 150, |g| {
        let kind = *g.choose(&CodecKind::SPARK);
        let len = g.len(200_000);
        let entropy = g.f64();
        let data = { let l = len; g.bytes(l, entropy) };
        let frame = compress_framed(kind, &data);
        match decompress_framed(&frame) {
            Ok((k, back)) if k == kind && back == data => Ok(()),
            Ok(_) => Err(format!("{kind}: round-trip mismatch at len {len}")),
            Err(e) => Err(format!("{kind}: {e} at len {len} entropy {entropy:.2}")),
        }
    });
}

#[test]
fn prop_codec_rejects_any_single_byte_corruption() {
    forall("codec corruption detection", 0xDEAD, 80, |g| {
        let kind = *g.choose(&CodecKind::SPARK);
        let dlen = g.len(5_000) + 13;
        let data = g.bytes(dlen, 0.4);
        let mut frame = compress_framed(kind, &data);
        let pos = g.rng.below(frame.len() as u64) as usize;
        let bit = 1u8 << g.rng.below(8);
        frame[pos] ^= bit;
        // Either an error, or (if the flip hit redundant codec padding)
        // the data still decodes *identically* — silent corruption of the
        // payload is the failure mode.
        match decompress_framed(&frame) {
            Err(_) => Ok(()),
            Ok((_, back)) if back == data => Ok(()),
            Ok(_) => Err(format!("{kind}: silent corruption at byte {pos} bit {bit}")),
        }
    });
}

#[test]
fn prop_serializers_round_trip_arbitrary_batches() {
    forall("serializer round-trip", 0x5E2, 120, |g| {
        let kind = if g.bool() { SerKind::Java } else { SerKind::Kryo };
        let n = g.len(60);
        let records: Vec<Record> = (0..n)
            .map(|_| match g.rng.below(3) {
                0 => {
                    let klen = g.len(40);
                    let vlen = g.len(300);
                    Record::Kv { key: g.bytes(klen, 0.7), value: g.bytes(vlen, 0.5) }
                }
                1 => {
                    let d = g.len(64);
                    Record::Vector((0..d).map(|_| g.rng.f32() * 100.0 - 50.0).collect())
                }
                _ => Record::Long(g.rng.next_u64() as i64),
            })
            .collect();
        let bytes = kind.serialize(&records);
        match kind.deserialize(&bytes) {
            Ok(back) if back == records => Ok(()),
            Ok(_) => Err(format!("{kind}: batch mismatch (n={n})")),
            Err(e) => Err(format!("{kind}: {e} (n={n})")),
        }
    });
}

// ---------- simulator ----------

#[test]
fn prop_sim_conserves_work() {
    forall("sim work conservation", 0x51A, 60, |g| {
        let mut cluster = ClusterSpec::mini();
        cluster.task_overhead = 0.0;
        let n = g.len(60) + 1;
        let mut total_cpu = 0.0;
        let mut total_disk = 0.0;
        let mut total_net = 0.0;
        let tasks: Vec<TaskSpec> = (0..n)
            .map(|_| {
                let cpu = g.f64() * 0.2;
                let dr = g.f64() * 5e6;
                let dw = g.f64() * 5e6;
                let ni = g.f64() * 5e6;
                total_cpu += cpu;
                total_disk += dr + dw;
                total_net += ni;
                TaskSpec::new(vec![
                    Phase::Cpu { secs: cpu },
                    Phase::DiskRead { bytes: dr },
                    Phase::DiskWrite { bytes: dw },
                    Phase::NetIn { bytes: ni },
                ])
            })
            .collect();
        let s = run_stage(&cluster, &tasks, &SimOpts { jitter: 0.0, seed: 1, straggler: None });
        let ok = (s.cpu_secs - total_cpu).abs() < 1e-6
            && (s.disk_bytes - total_disk).abs() < 1.0
            && (s.net_bytes - total_net).abs() < 1.0
            && s.task_time.len() == n;
        if !ok {
            return Err(format!(
                "conservation broke: cpu {} vs {total_cpu}, disk {} vs {total_disk}",
                s.cpu_secs, s.disk_bytes
            ));
        }
        // Lower bound: aggregate work / aggregate capacity.
        let lb = (total_cpu / cluster.total_cores() as f64)
            .max(total_disk / cluster.total_disk_bw())
            .max(total_net / cluster.total_net_bw());
        if s.duration + 1e-9 < lb {
            return Err(format!("duration {} below roofline {lb}", s.duration));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_respects_core_capacity() {
    forall("core capacity", 0xC04E, 40, |g| {
        let mut cluster = ClusterSpec::mini();
        cluster.task_overhead = 0.0;
        let cores = cluster.total_cores() as usize;
        let n = g.len(40) + cores;
        let secs = 0.1 + g.f64();
        let tasks: Vec<TaskSpec> =
            (0..n).map(|_| TaskSpec::new(vec![Phase::Cpu { secs }])).collect();
        let s = run_stage(&cluster, &tasks, &SimOpts { jitter: 0.0, seed: 2, straggler: None });
        let waves = (n as f64 / cores as f64).ceil();
        let expect = waves * secs;
        if (s.duration - expect).abs() > 1e-6 {
            return Err(format!("{n} tasks on {cores} cores: {} vs {expect}", s.duration));
        }
        Ok(())
    });
}

#[test]
fn sim_deterministic_across_runs() {
    let cluster = ClusterSpec::marenostrum();
    let job = Workload::SortByKey1B.job();
    let conf = SparkConf::default();
    let a = run(&job, &conf, &cluster, &SimOpts::default());
    let b = run(&job, &conf, &cluster, &SimOpts::default());
    assert_eq!(a.duration, b.duration);
    assert_eq!(a.stages.len(), b.stages.len());
}

// ---------- engine ----------

#[test]
fn prop_engine_duration_monotone_in_records() {
    forall("engine monotone in records", 0xE17, 12, |g| {
        let cluster = ClusterSpec::marenostrum();
        let conf = SparkConf::default();
        let base = 50_000_000 + g.int(0, 100_000_000);
        let small = workloads::sort_by_key(base, 640);
        let big = workloads::sort_by_key(base * 2, 640);
        let t_small =
            run(&small, &conf, &cluster, &SimOpts { jitter: 0.0, seed: 3, straggler: None }).effective_duration();
        let t_big =
            run(&big, &conf, &cluster, &SimOpts { jitter: 0.0, seed: 3, straggler: None }).effective_duration();
        if t_big <= t_small {
            return Err(format!("2× records not slower: {t_small} vs {t_big} (base {base})"));
        }
        Ok(())
    });
}

#[test]
fn engine_crash_monotone_in_shuffle_fraction() {
    // If sort-by-key crashes at fraction f, it must crash at every
    // fraction below f too (the OOM floor only tightens).
    let cluster = ClusterSpec::marenostrum();
    let job = Workload::SortByKey1B.job();
    let mut crashed_above = false;
    for f in ["0.30", "0.20", "0.12", "0.08", "0.05"] {
        let conf = SparkConf::default()
            .with("spark.shuffle.memoryFraction", f)
            .with("spark.storage.memoryFraction", "0.5");
        let r = run(&job, &conf, &cluster, &SimOpts { jitter: 0.0, seed: 1, straggler: None });
        if crashed_above {
            assert!(
                r.crashed.is_some(),
                "crashed at a higher fraction but survived at {f}"
            );
        }
        crashed_above = crashed_above || r.crashed.is_some();
    }
    assert!(crashed_above, "no fraction crashed — the OOM mechanism is dead");
}

#[test]
fn engine_stage_accounting_sums_to_job() {
    let cluster = ClusterSpec::marenostrum();
    let r = run(
        &Workload::KMeans100M.job(),
        &SparkConf::default(),
        &cluster,
        &SimOpts::default(),
    );
    assert!(r.crashed.is_none());
    let sum: f64 = r.stages.iter().map(|s| s.duration).sum();
    assert!((sum - r.duration).abs() < 1e-9 * r.duration.max(1.0));
    assert_eq!(r.stages.len(), 21); // gen+cache + 10 × (map, reduce)
}

#[test]
fn engine_rejects_malformed_jobs_gracefully() {
    let cluster = ClusterSpec::mini();
    let bad = Job::new("no-source").op(Op::SortByKey { reducers: 4 });
    let r = run(&bad, &SparkConf::default(), &cluster, &SimOpts::default());
    assert!(r.crashed.is_some());
    assert!(r.crashed.unwrap().contains("plan error"));
}

#[test]
fn engine_zero_sized_dataset_runs() {
    let cluster = ClusterSpec::mini();
    let d = Dataset::kv(0, 10, 90, 4);
    let job = Job::new("empty")
        .op(Op::Generate { out: d, cpu_ns_per_record: 100.0 })
        .op(Op::SortByKey { reducers: 4 })
        .op(Op::Action);
    let r = run(&job, &SparkConf::default(), &cluster, &SimOpts::default());
    assert!(r.crashed.is_none());
    assert!(r.duration >= 0.0 && r.duration.is_finite());
}

// ---------- tuner ----------

#[test]
fn prop_tuner_never_worse_than_baseline_and_within_budget() {
    forall("tuner invariants", 0x7E57, 60, |g| {
        // Random synthetic response surface over the 6 methodology axes.
        let effects: Vec<f64> = (0..12).map(|_| 0.6 + g.f64() * 0.9).collect();
        let crash_mf17 = g.bool();
        let threshold = if g.bool() { 0.0 } else { 0.1 };
        let mut runner = |c: &SparkConf| -> f64 {
            if crash_mf17 && c.shuffle_memory_fraction == 0.1 {
                return f64::INFINITY;
            }
            let mut t = 100.0;
            if c.serializer == SerKind::Kryo {
                t *= effects[0];
            }
            match c.shuffle_manager {
                sparktune::conf::ShuffleManagerKind::Hash => t *= effects[1],
                sparktune::conf::ShuffleManagerKind::TungstenSort => t *= effects[2],
                _ => {}
            }
            if !c.shuffle_compress {
                t *= effects[3];
            }
            if c.shuffle_memory_fraction == 0.4 {
                t *= effects[4];
            }
            if c.shuffle_memory_fraction == 0.1 {
                t *= effects[5];
            }
            if !c.shuffle_spill_compress {
                t *= effects[6];
            }
            if c.shuffle_file_buffer == 96 * 1024 {
                t *= effects[7];
            }
            if c.shuffle_file_buffer == 15 * 1024 {
                t *= effects[8];
            }
            t
        };
        let out = tune(&mut runner, &TuneOpts { threshold, ..TuneOpts::default() });
        if out.best > out.baseline + 1e-9 {
            return Err(format!("best {} worse than baseline {}", out.best, out.baseline));
        }
        if out.runs() > 10 {
            return Err(format!("{} runs > 10", out.runs()));
        }
        for t in &out.trials {
            if t.kept && !(t.improvement > threshold) {
                return Err(format!("kept {:?} with improvement {}", t.step, t.improvement));
            }
            if t.kept && t.duration.is_infinite() {
                return Err("kept a crashed configuration".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_grid_decode_total_and_valid() {
    assert_eq!(grid_size(), 216);
    forall("grid decode valid", 0x64D, 216, |g| {
        let idx = g.rng.below(216) as usize;
        let conf = grid_conf(idx);
        conf.validate().map_err(|e| format!("grid {idx}: {e}"))
    });
}

// ---------- cross-layer: tuner drives the real engine ----------

#[test]
fn tuned_configuration_reproduces_when_replayed() {
    // The tuner's reported best time must match an independent run of the
    // final configuration (no hidden state in the runner).
    let cluster = ClusterSpec::marenostrum();
    let job = Workload::SortByKey1B.job();
    let mut runner = |c: &SparkConf| {
        run(&job, c, &cluster, &SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None }).effective_duration()
    };
    let out = tune(&mut runner, &TuneOpts { threshold: 0.10, ..TuneOpts::default() });
    let replay = run(&job, &out.best_conf, &cluster, &SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None });
    assert!(replay.crashed.is_none());
    assert!((replay.duration - out.best).abs() < 1e-9, "{} vs {}", replay.duration, out.best);
}

#[test]
fn threshold_zero_keeps_at_least_as_much_as_threshold_ten() {
    let cluster = ClusterSpec::marenostrum();
    for w in [Workload::SortByKey1B, Workload::AggregateByKey2B] {
        let job = w.job();
        let mk = |thr: f64| {
            let mut runner = |c: &SparkConf| {
                run(&job, c, &cluster, &SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None })
                    .effective_duration()
            };
            tune(&mut runner, &TuneOpts { threshold: thr, ..TuneOpts::default() })
        };
        let loose = mk(0.0);
        let strict = mk(0.10);
        assert!(
            loose.best <= strict.best + 1e-9,
            "{}: threshold 0 best {} worse than threshold 10% best {}",
            w.name(),
            loose.best,
            strict.best
        );
    }
}
