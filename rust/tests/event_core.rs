//! Integration tests for the task-granular event-driven scheduler core:
//!
//! * **determinism** — same `(conf, seed)` produces bit-identical
//!   `JobResult`s across repeated runs and across `TrialExecutor` thread
//!   counts, including with delay scheduling, speculation, and the
//!   straggler model all enabled;
//! * **barrier equivalence** — on a linear stage DAG under FIFO the
//!   event clock reproduces the legacy barrier accounting (makespan ==
//!   sum of stage durations; absolute magnitudes match the seed test
//!   expectations, which were calibrated on the barrier path);
//! * **golden zero-jitter path** — with jitter off, the task-granular
//!   knobs (`spark.locality.wait`, `spark.speculation`) are exact no-ops
//!   on the PR-1 stage-granular numbers;
//! * **multi-tenancy** — ≥ 4 concurrent jobs run under both FIFO and
//!   FAIR with the policies' characteristic completion orderings.

use sparktune::cluster::ClusterSpec;
use sparktune::conf::SparkConf;
use sparktune::engine::{run, run_all};
use sparktune::experiments::tenancy::run_tenancy;
use sparktune::sim::{SchedulerMode, SimOpts, Straggler};
use sparktune::tuner::baselines::{exhaustive, exhaustive_parallel, grid_conf};
use sparktune::tuner::TrialExecutor;
use sparktune::workloads::{self, Workload};

// ---------- determinism ----------

#[test]
fn job_results_bit_identical_across_runs() {
    let cluster = ClusterSpec::marenostrum();
    let conf = SparkConf::default().with("spark.serializer", "kryo");
    for w in [Workload::SortByKey1B, Workload::KMeans100M] {
        let job = w.job();
        let a = run(&job, &conf, &cluster, &SimOpts::default());
        let b = run(&job, &conf, &cluster, &SimOpts::default());
        assert!(a.crashed.is_none());
        assert_eq!(a.duration, b.duration, "{}", w.name());
        assert_eq!(a.stages.len(), b.stages.len());
        for (x, y) in a.stages.iter().zip(&b.stages) {
            assert_eq!(x.duration, y.duration, "{}: stage {}", w.name(), x.name);
            assert_eq!(x.cpu_secs, y.cpu_secs);
            assert_eq!(x.spilled_bytes, y.spilled_bytes);
        }
    }
}

#[test]
fn trial_results_bit_identical_across_thread_counts() {
    // The acceptance bar: grid-search trials on ≥ 4 threads must return
    // results identical to sequential execution.
    let cluster = ClusterSpec::mini();
    let job = Workload::MiniSortByKey.job();
    let eval = |c: &SparkConf| {
        run(&job, c, &cluster, &SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None }).effective_duration()
    };
    let confs: Vec<SparkConf> = (0..40).map(grid_conf).collect();
    let seq = TrialExecutor::new(1).evaluate(&confs, eval);
    for threads in [2usize, 4, 8] {
        let par = TrialExecutor::new(threads).evaluate(&confs, eval);
        assert_eq!(seq, par, "{threads}-thread trial results diverged from sequential");
    }

    // Full grid search end-to-end: identical optimum and trial list.
    let mut seq_runner = |c: &SparkConf| eval(c);
    let sequential = exhaustive(&mut seq_runner);
    let parallel = exhaustive_parallel(eval, &TrialExecutor::new(4));
    assert_eq!(sequential.best, parallel.best);
    assert_eq!(sequential.best_conf, parallel.best_conf);
    assert_eq!(sequential.trials.len(), parallel.trials.len());
}

#[test]
fn speculation_and_locality_runs_bit_identical() {
    // Everything on at once — delay scheduling, speculation, stragglers —
    // must still reproduce bit for bit across repeated runs.
    let cluster = ClusterSpec::marenostrum();
    let conf = SparkConf::default()
        .with("spark.speculation", "true")
        .with("spark.locality.wait", "1s");
    let opts = SimOpts {
        jitter: 0.04,
        seed: 0xBEEF,
        straggler: Some(Straggler { prob: 0.03, factor: 8.0 }),
    };
    let job = Workload::KMeans100M.job();
    let a = run(&job, &conf, &cluster, &opts);
    let b = run(&job, &conf, &cluster, &opts);
    assert!(a.crashed.is_none());
    assert_eq!(a.duration, b.duration);
    for (x, y) in a.stages.iter().zip(&b.stages) {
        assert_eq!(x.duration, y.duration, "stage {}", x.name);
        assert_eq!(x.speculated, y.speculated);
        assert_eq!(x.locality_hits, y.locality_hits);
        assert_eq!(x.cpu_secs, y.cpu_secs);
    }
}

#[test]
fn straggler_trials_bit_identical_across_thread_counts() {
    // TrialExecutor thread-count invariance must survive the new
    // code paths: speculation + locality + straggler jitter per trial.
    let cluster = ClusterSpec::mini();
    let job = workloads::straggler_probe(2_000_000, 32);
    let eval = |c: &SparkConf| {
        run(
            &job,
            c,
            &cluster,
            &SimOpts {
                jitter: 0.04,
                seed: 0x7E57,
                straggler: Some(Straggler { prob: 0.1, factor: 6.0 }),
            },
        )
        .effective_duration()
    };
    let confs: Vec<SparkConf> = (0..16)
        .map(|i| {
            let mut c = grid_conf(i * 13 % 216);
            if i % 2 == 0 {
                c.set("spark.speculation", "true").unwrap();
            }
            if i % 3 == 0 {
                c.set("spark.locality.wait", "0s").unwrap();
            }
            c
        })
        .collect();
    let seq = TrialExecutor::new(1).evaluate(&confs, eval);
    for threads in [2usize, 4, 8] {
        let par = TrialExecutor::new(threads).evaluate(&confs, eval);
        assert_eq!(seq, par, "{threads}-thread straggler trials diverged from sequential");
    }
}

// ---------- golden: zero-jitter path pins the PR-1 numbers ----------

#[test]
fn zero_jitter_golden_knobs_are_noops() {
    // With jitter off and no stragglers, wave completions are
    // simultaneous, so delay scheduling never holds (every preferred
    // node has a free core at each admission instant) and no task ever
    // exceeds the speculation threshold. The golden contract: the
    // locality/speculation knobs leave every makespan of the PR-1
    // stage-granular core untouched.
    let cluster = ClusterSpec::marenostrum();
    let opts = SimOpts { jitter: 0.0, seed: 0x90_1D, straggler: None };
    let golden = SparkConf::default().with("spark.locality.wait", "0s");
    for w in [Workload::SortByKey1B, Workload::KMeans100M, Workload::AggregateByKey2B] {
        let job = w.job();
        let base = run(&job, &golden, &cluster, &opts);
        assert!(base.crashed.is_none(), "{}", w.name());
        // Default 3s wait — identical.
        let waited = run(&job, &SparkConf::default(), &cluster, &opts);
        assert_eq!(
            base.duration,
            waited.duration,
            "{}: locality.wait must be a no-op at zero jitter",
            w.name()
        );
        // Speculation on — identical, zero clones.
        let spec_conf = SparkConf::default()
            .with("spark.locality.wait", "0s")
            .with("spark.speculation", "true");
        let spec = run(&job, &spec_conf, &cluster, &opts);
        assert_eq!(
            base.duration,
            spec.duration,
            "{}: speculation must be a no-op at zero jitter",
            w.name()
        );
        assert_eq!(spec.stages.iter().map(|s| s.speculated).sum::<usize>(), 0);
        // And the stage sum still telescopes (barrier equivalence).
        let sum: f64 = base.stages.iter().map(|s| s.duration).sum();
        assert!((sum - base.duration).abs() < 1e-9 * base.duration.max(1.0));
    }
}

// ---------- barrier equivalence on linear DAGs ----------

#[test]
fn linear_dags_reproduce_barrier_accounting() {
    // Every paper workload is a linear stage chain: under FIFO the event
    // core must make the makespan telescope into the per-stage durations
    // — the golden equivalence with the retired barrier path (the seed's
    // absolute duration expectations all assume it).
    let cluster = ClusterSpec::marenostrum();
    let conf = SparkConf::default();
    for w in Workload::PAPER {
        let r = run(&w.job(), &conf, &cluster, &SimOpts::default());
        assert!(r.crashed.is_none(), "{}: {:?}", w.name(), r.crashed);
        let sum: f64 = r.stages.iter().map(|s| s.duration).sum();
        let dev = (sum - r.duration).abs() / r.duration.max(1e-12);
        assert!(
            dev < 1e-9,
            "{}: stage sum {sum} vs makespan {} (rel dev {dev:e})",
            w.name(),
            r.duration
        );
    }
}

#[test]
fn single_job_batch_matches_solo_run() {
    // run() is defined as run_all() of a 1-batch — but assert it anyway:
    // the multi-job machinery must be invisible for a lone job.
    let cluster = ClusterSpec::marenostrum();
    let conf = SparkConf::default().with("spark.serializer", "kryo");
    let job = Workload::SortByKey1B.job();
    let solo = run(&job, &conf, &cluster, &SimOpts::default());
    let batch = run_all(std::slice::from_ref(&job), &conf, &cluster, &SimOpts::default());
    assert_eq!(batch.results.len(), 1);
    assert_eq!(batch.results[0].duration, solo.duration);
    assert_eq!(batch.makespan, solo.duration);
}

// ---------- multi-tenancy: FIFO vs FAIR ----------

#[test]
fn four_tenants_fifo_vs_fair_on_the_paper_cluster() {
    let cluster = ClusterSpec::marenostrum();
    let jobs = workloads::multi_tenant(4, 100_000_000, 640);
    let conf = SparkConf::default().with("spark.serializer", "kryo");
    let opts = SimOpts::default();

    let solo = run(&jobs[0], &conf, &cluster, &opts);
    assert!(solo.crashed.is_none());

    let fifo = run_tenancy(&jobs, &conf, &cluster, SchedulerMode::Fifo, &opts);
    let fair = run_tenancy(&jobs, &conf, &cluster, SchedulerMode::Fair, &opts);
    assert_eq!(fifo.completions().len(), 4, "all four tenants must finish under FIFO");
    assert_eq!(fair.completions().len(), 4, "all four tenants must finish under FAIR");

    // FIFO: completion times follow submission order, and the head job
    // runs near its solo time.
    let cf = fifo.completions();
    for w in cf.windows(2) {
        assert!(w[0] <= w[1] + 1e-9, "FIFO order violated: {cf:?}");
    }
    assert!(cf[0] < solo.duration * 1.7, "FIFO head {:.1}s vs solo {:.1}s", cf[0], solo.duration);

    // FAIR: the head job pays for fairness; the field bunches together.
    assert!(
        fair.completions()[0] > cf[0] * 1.3,
        "FAIR head {:.1}s should be well above FIFO head {:.1}s",
        fair.completions()[0],
        cf[0]
    );
    assert!(
        fair.spread() < fifo.spread() * 0.5,
        "FAIR spread {:.1}s !< half FIFO spread {:.1}s",
        fair.spread(),
        fifo.spread()
    );

    // Both policies are work-conserving: comparable makespans.
    let ratio = fair.batch.makespan / fifo.batch.makespan;
    assert!(
        (0.6..1.7).contains(&ratio),
        "fifo makespan {:.1}s vs fair {:.1}s",
        fifo.batch.makespan,
        fair.batch.makespan
    );
}

#[test]
fn scheduler_mode_flows_from_conf() {
    // run_all reads spark.scheduler.mode off the configuration: setting
    // FAIR through the string API must change the outcome for the head
    // job while leaving solo runs untouched.
    let cluster = ClusterSpec::mini();
    let jobs = workloads::multi_tenant(4, 2_000_000, 16);
    let fifo_conf = SparkConf::default();
    let fair_conf = SparkConf::default().with("spark.scheduler.mode", "FAIR");
    let opts = SimOpts::default();

    let head_fifo = run_all(&jobs, &fifo_conf, &cluster, &opts).results[0].duration;
    let head_fair = run_all(&jobs, &fair_conf, &cluster, &opts).results[0].duration;
    assert!(
        head_fair > head_fifo * 1.2,
        "FAIR head {head_fair:.2}s should exceed FIFO head {head_fifo:.2}s"
    );

    let solo_fifo = run(&jobs[0], &fifo_conf, &cluster, &opts).duration;
    let solo_fair = run(&jobs[0], &fair_conf, &cluster, &opts).duration;
    assert_eq!(solo_fifo, solo_fair, "scheduler mode must not affect a lone job");
}
