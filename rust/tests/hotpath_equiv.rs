//! Golden equivalence suite for the event-core hot-path overhaul.
//!
//! The indexed event queue (hand-rolled min-heap + dirty-resource rate
//! propagation + launch-ordered speculation queues) must be a pure
//! *performance* change: for any scenario, the [`Discovery::Indexed`]
//! core reproduces the self-verifying [`Discovery::Scan`] reference —
//! which rescans every live copy per event and asserts the cached
//! fair-share rates fresh — **bit for bit**, across FIFO/FAIR,
//! delay scheduling, speculation, the straggler model, mid-flight
//! submission, and degenerate stages. Likewise, plan-once pricing
//! (`prepare` + `run_planned`) must be bit-identical to re-planning per
//! trial, for solo runs, multi-tenant batches, and crashing confs.
//!
//! The incremental re-pricing suite extends the same contract to
//! timeline forking: `run_planned_recording` / `run_planned_from` must
//! reproduce full pricing bit for bit (modulo the `replayed_events` /
//! `forked_trials` bookkeeping, which `SimStats::logical` projects
//! away) across FIFO/FAIR × locality × speculation × straggler, on the
//! self-verifying Scan core as well as the Indexed one. The per-field
//! sensitivity classifier decides the resume point — including
//! certified policy forks (locality wait, speculation) the coarse
//! three-way oracle calls Global — and mid-stage cadence snapshots are
//! resume points too, so deep jobs fork from *inside* a late stage.
//! The byte-budgeted fork store must stay lossless: a trial whose
//! family was evicted re-prices in full, never resumes a wrong
//! timeline, and the least-recently-matched entry is the victim. All
//! of it for any service worker count.

use sparktune::cluster::{ClusterSpec, NodeId};
use sparktune::conf::SparkConf;
use sparktune::engine::{
    prepare, run, run_all, run_all_planned, run_planned, run_planned_from, run_planned_recording,
    Job, JobPlan,
};
use sparktune::sim::{
    scheduler_for, Discovery, EventSim, PoolSpec, SchedulerMode, SimOpts, SimPolicy, SimStats,
    SpecPolicy, StageCompletion, Straggler, TaskSpec,
};
use sparktune::sim::Phase;
use sparktune::tuner::baselines::{grid_conf, grid_size};
use sparktune::workloads::{self, Workload};
use std::sync::Arc;

/// Bitwise comparison of two completion streams: event order, clocks,
/// meters, locality/speculation counters, and winning-node placements.
fn assert_streams_identical(scan: &[StageCompletion], indexed: &[StageCompletion], what: &str) {
    assert_eq!(scan.len(), indexed.len(), "{what}: completion counts diverged");
    for (x, y) in scan.iter().zip(indexed) {
        assert_eq!(x.handle, y.handle, "{what}: emission order diverged");
        assert_eq!(x.job, y.job, "{what}");
        assert_eq!(x.at.to_bits(), y.at.to_bits(), "{what}: clock diverged at stage {}", x.handle);
        assert_eq!(x.stats.duration.to_bits(), y.stats.duration.to_bits(), "{what}");
        assert_eq!(x.stats.cpu_secs.to_bits(), y.stats.cpu_secs.to_bits(), "{what}");
        assert_eq!(x.stats.disk_bytes.to_bits(), y.stats.disk_bytes.to_bits(), "{what}");
        assert_eq!(x.stats.net_bytes.to_bits(), y.stats.net_bytes.to_bits(), "{what}");
        assert_eq!(x.stats.tasks, y.stats.tasks, "{what}");
        assert_eq!(x.stats.locality_hits, y.stats.locality_hits, "{what}");
        assert_eq!(x.stats.speculated, y.stats.speculated, "{what}");
        assert_eq!(x.task_nodes, y.task_nodes, "{what}: winning placements diverged");
    }
}

/// Run the same scripted scenario on both cores and compare streams.
fn both_cores(
    cluster: &ClusterSpec,
    mode: SchedulerMode,
    policy: SimPolicy,
    what: &str,
    script: impl Fn(&mut EventSim<'_>) -> Vec<StageCompletion>,
) -> (SimStats, SimStats) {
    let mut scan = EventSim::with_discovery(cluster, scheduler_for(mode), policy, Discovery::Scan);
    let scan_out = script(&mut scan);
    let mut idx =
        EventSim::with_discovery(cluster, scheduler_for(mode), policy, Discovery::Indexed);
    let idx_out = script(&mut idx);
    assert_streams_identical(&scan_out, &idx_out, what);
    (scan.stats(), idx.stats())
}

/// A mixed-phase task set exercising every phase kind and node.
fn mixed_tasks(n: usize, nodes: u32, pin: bool) -> Vec<TaskSpec> {
    (0..n)
        .map(|k| {
            let t = TaskSpec::new(vec![
                Phase::Fixed { secs: 0.002 * (1 + k % 3) as f64 },
                Phase::NetIn { bytes: 0.5e6 * (1 + k % 5) as f64 },
                Phase::DiskRead { bytes: 1e6 * (1 + k % 4) as f64 },
                Phase::Cpu { secs: 0.05 + (k % 7) as f64 * 0.02 },
                Phase::DiskWrite { bytes: 2e6 },
            ]);
            if pin {
                t.on((k as u32 % nodes) as NodeId)
            } else {
                t
            }
        })
        .collect()
}

#[test]
fn fifo_and_fair_multi_job_streams_match() {
    let cluster = ClusterSpec::mini();
    for mode in SchedulerMode::ALL {
        let (ss, is) = both_cores(
            &cluster,
            mode,
            SimPolicy::default(),
            &format!("{mode} multi-job"),
            |sim| {
                for j in 0..4usize {
                    sim.submit(
                        j,
                        &mixed_tasks(18, 4, j % 2 == 0),
                        &SimOpts { jitter: 0.06, seed: 40 + j as u64, straggler: None },
                    );
                }
                sim.drain()
            },
        );
        assert_eq!(ss.events, is.events, "{mode}: event counts diverged");
        assert_eq!(ss.heap_ops(), 0);
        assert!(is.heap_ops() > 0);
    }
}

#[test]
fn locality_wait_hold_and_expiry_streams_match() {
    // Pinned tasks contend for two nodes under a range of waits: holds,
    // hold-expiry events, and degradation to ANY all cross the cores.
    let mut cluster = ClusterSpec::mini();
    cluster.nodes = 2;
    cluster.cores_per_node = 2;
    for wait in [0.0, 0.05, 0.4, 5.0] {
        both_cores(
            &cluster,
            SchedulerMode::Fifo,
            SimPolicy { locality_wait: wait, speculation: None },
            &format!("locality wait {wait}"),
            |sim| {
                for j in 0..3usize {
                    let tasks: Vec<TaskSpec> = (0..8)
                        .map(|k| {
                            TaskSpec::new(vec![Phase::Cpu { secs: 0.2 + (k % 3) as f64 * 0.05 }])
                                .on(0)
                        })
                        .collect();
                    sim.submit(
                        j,
                        &tasks,
                        &SimOpts { jitter: 0.03, seed: 9 + j as u64, straggler: None },
                    );
                }
                sim.drain()
            },
        );
    }
}

#[test]
fn speculation_and_straggler_streams_match() {
    // Clone launches, first-finisher-wins races, sibling cancellation
    // with mid-stream flow withdrawal and meter refunds.
    let cluster = ClusterSpec::mini();
    for (quantile, multiplier) in [(0.75, 1.5), (0.3, 1.2)] {
        both_cores(
            &cluster,
            SchedulerMode::Fair,
            SimPolicy {
                locality_wait: 0.1,
                speculation: Some(SpecPolicy { quantile, multiplier }),
            },
            &format!("speculation q={quantile} m={multiplier}"),
            |sim| {
                sim.set_pool(1, PoolSpec { weight: 2.0, min_share: 1 });
                for j in 0..3usize {
                    sim.submit(
                        j,
                        &mixed_tasks(16, 4, true),
                        &SimOpts {
                            jitter: 0.05,
                            seed: 77 + j as u64,
                            straggler: Some(Straggler { prob: 0.3, factor: 8.0 }),
                        },
                    );
                }
                sim.drain()
            },
        );
    }
}

#[test]
fn mid_flight_submission_streams_match() {
    // Stages arriving while the core is busy (the engine's DAG-walk
    // pattern): drain one completion, submit more, repeat.
    let cluster = ClusterSpec::mini();
    both_cores(
        &cluster,
        SchedulerMode::Fifo,
        SimPolicy { locality_wait: 0.2, speculation: None },
        "mid-flight submission",
        |sim| {
            let mut out = Vec::new();
            let o = |seed: u64| SimOpts { jitter: 0.04, seed, straggler: None };
            sim.submit(0, &mixed_tasks(10, 4, true), &o(1));
            sim.submit(1, &[], &o(2));
            out.push(sim.advance().expect("empty stage completes"));
            // Submit against a busy cluster, including a NaN-phase task
            // (must degrade to a noop, not wedge either core).
            sim.submit(
                2,
                &[
                    TaskSpec::new(vec![Phase::Cpu { secs: f64::NAN }, Phase::Cpu { secs: 0.3 }]),
                    TaskSpec::new(vec![Phase::DiskWrite { bytes: 5e6 }]).on(1),
                ],
                &o(3),
            );
            out.push(sim.advance().expect("more work pending"));
            sim.submit(0, &mixed_tasks(6, 4, false), &o(4));
            out.extend(sim.drain());
            assert!(sim.advance().is_none());
            out
        },
    );
}

#[test]
fn indexed_core_does_strictly_less_scan_work() {
    // The CI acceptance counter: on a real multi-wave scenario the
    // indexed core's dirty-resource flow rolls must be strictly fewer
    // than events × live copies (what per-event rescans would touch).
    let cluster = ClusterSpec::mini();
    let (ss, is) = both_cores(
        &cluster,
        SchedulerMode::Fifo,
        SimPolicy::default(),
        "scan-work budget",
        |sim| {
            for j in 0..2usize {
                sim.submit(
                    j,
                    &mixed_tasks(64, 4, false),
                    &SimOpts { jitter: 0.05, seed: 5 + j as u64, straggler: None },
                );
            }
            sim.drain()
        },
    );
    // Both cores rolled the same flows (shared dirty rule)...
    assert_eq!(ss.flow_rolls, is.flow_rolls);
    // ...and that is strictly below the rescan-equivalent work.
    assert!(is.events > 0);
    assert!(
        is.flow_rolls < is.live_copy_event_sum,
        "indexed core rolled {} flows vs {} rescan-equivalent",
        is.flow_rolls,
        is.live_copy_event_sum
    );
    assert!(is.scan_work_saved() > 0);
}

// ---------- plan once / price many ----------

type EngineResult = sparktune::engine::JobResult;

fn job_results_identical(a: &EngineResult, b: &EngineResult) -> bool {
    a.job == b.job
        && a.duration.to_bits() == b.duration.to_bits()
        && a.crashed == b.crashed
        && a.stages.len() == b.stages.len()
        && a.stages.iter().zip(&b.stages).all(|(x, y)| {
            x.name == y.name
                && x.duration.to_bits() == y.duration.to_bits()
                && x.cpu_secs.to_bits() == y.cpu_secs.to_bits()
                && x.disk_bytes.to_bits() == y.disk_bytes.to_bits()
                && x.net_bytes.to_bits() == y.net_bytes.to_bits()
                && x.spilled_bytes == y.spilled_bytes
                && x.gc_factor.to_bits() == y.gc_factor.to_bits()
                && x.cache_hit_fraction.map(f64::to_bits) == y.cache_hit_fraction.map(f64::to_bits)
                && x.locality_hits == y.locality_hits
                && x.speculated == y.speculated
        })
}

#[test]
fn plan_once_matches_replanning_across_the_grid() {
    // One job, a spread of grid candidates (including crashing memory
    // geometries): sharing the plan must not change a bit of any result.
    let cluster = ClusterSpec::mini();
    let job = Workload::MiniSortByKey.job();
    let plan = prepare(&job).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    for i in 0..24 {
        let conf = grid_conf(i * 9 % grid_size());
        let fresh = run(&job, &conf, &cluster, &opts);
        let shared = run_planned(&plan, &conf, &cluster, &opts);
        assert!(job_results_identical(&fresh, &shared), "grid conf {i} diverged");
    }
}

#[test]
fn plan_once_matches_replanning_for_kmeans_and_speculation() {
    // The iterative DAG (cache writer + per-iteration parents) is the
    // planner's hardest shape; cross it with the task-granular knobs.
    let cluster = ClusterSpec::marenostrum();
    let job = Workload::KMeans100M.job();
    let plan = prepare(&job).unwrap();
    let conf = SparkConf::default()
        .with("spark.speculation", "true")
        .with("spark.locality.wait", "1s");
    let opts = SimOpts {
        jitter: 0.04,
        seed: 0xBEEF,
        straggler: Some(Straggler { prob: 0.03, factor: 8.0 }),
    };
    let fresh = run(&job, &conf, &cluster, &opts);
    let shared = run_planned(&plan, &conf, &cluster, &opts);
    assert!(fresh.crashed.is_none());
    assert!(job_results_identical(&fresh, &shared));
    assert_eq!(fresh.sim, shared.sim, "identical work counters");
}

#[test]
fn planned_multi_tenant_batch_matches_replanned() {
    let cluster = ClusterSpec::mini();
    let jobs: Vec<Job> = workloads::mixed_tenants(3, 2_000_000, 16);
    let plans: Vec<Arc<JobPlan>> = jobs.iter().map(|j| prepare(j).unwrap()).collect();
    for mode in ["FIFO", "FAIR"] {
        let conf = SparkConf::default().with("spark.scheduler.mode", mode);
        let a = run_all(&jobs, &conf, &cluster, &SimOpts::default());
        let b = run_all_planned(&plans, &conf, &cluster, &SimOpts::default());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{mode}");
        for (x, y) in a.results.iter().zip(&b.results) {
            assert!(job_results_identical(x, y), "{mode}: {} diverged", x.job);
        }
    }
}

// ---------- incremental re-pricing (timeline forking) ----------

/// Iterative cache-prefixed workload: generate + MEMORY_ONLY cache,
/// then cache-read → map → shuffle iterations. The prefix is
/// insensitive to every shuffle-class parameter, so forks have a real
/// shared timeline to inherit.
fn iterative_job() -> Job {
    workloads::kmeans(400_000, 32, 8, 3, 16)
}

#[test]
fn incremental_repricing_matches_full_bitwise_across_the_matrix() {
    // FIFO/FAIR × delay-scheduling/speculation × straggler model, each
    // crossed with the decision list's shuffle-class deltas: the forked
    // run must equal the full-reprice oracle bit for bit, and the
    // recording run must equal a plain run bit for bit (including every
    // core work counter — recording must not perturb the timeline).
    let cluster = ClusterSpec::mini();
    let plan = prepare(&iterative_job()).unwrap();
    let bases = [
        ("fifo", SparkConf::default()),
        ("fair", SparkConf::default().with("spark.scheduler.mode", "FAIR")),
        (
            "speculation+locality",
            SparkConf::default()
                .with("spark.speculation", "true")
                .with("spark.locality.wait", "1s"),
        ),
    ];
    let opt_sets = [
        ("plain", SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None }),
        (
            "straggler",
            SimOpts {
                jitter: 0.05,
                seed: 0xBEEF,
                straggler: Some(Straggler { prob: 0.1, factor: 6.0 }),
            },
        ),
    ];
    let deltas: [(&str, &[(&str, &str)]); 3] = [
        ("kryo", &[("spark.serializer", "kryo")]),
        ("no shuffle compression", &[("spark.shuffle.compress", "false")]),
        (
            "tungsten+lzf",
            &[
                ("spark.shuffle.manager", "tungsten-sort"),
                ("spark.io.compression.codec", "lzf"),
            ],
        ),
    ];
    for (bname, base) in &bases {
        for (oname, opts) in &opt_sets {
            let (rec, fork) = run_planned_recording(&plan, base, &cluster, opts);
            let plain = run_planned(&plan, base, &cluster, opts);
            assert!(job_results_identical(&rec, &plain), "{bname}/{oname}: recording diverged");
            assert_eq!(rec.sim, plain.sim, "{bname}/{oname}: recording perturbed the counters");
            for (dname, delta) in &deltas {
                let mut conf = base.clone();
                for (k, v) in *delta {
                    conf = conf.with(k, v);
                }
                let full = run_planned(&plan, &conf, &cluster, opts);
                let forked = run_planned_from(&fork, &plan, &conf, &cluster, opts)
                    .unwrap_or_else(|| panic!("{bname}/{oname}/{dname}: fork declined"));
                assert!(
                    job_results_identical(&full, &forked),
                    "{bname}/{oname}/{dname}: forked result diverged from full pricing"
                );
                assert_eq!(
                    forked.sim.logical(),
                    full.sim.logical(),
                    "{bname}/{oname}/{dname}: logical core counters diverged"
                );
                assert_eq!(forked.sim.forked_trials, 1, "{bname}/{oname}/{dname}");
                assert!(forked.sim.replayed_events > 0, "{bname}/{oname}/{dname}");
                assert!(
                    forked.sim.processed_events() < full.sim.events,
                    "{bname}/{oname}/{dname}: fork processed {} of {} events",
                    forked.sim.processed_events(),
                    full.sim.events
                );
            }
        }
    }
}

#[test]
fn checkpoint_resume_reproduces_on_the_scan_core() {
    // The Scan-core oracle: resume a checkpoint on the self-verifying
    // reference core (which asserts the indexed bookkeeping invariants
    // at every event), under speculation + stragglers + FAIR pools, and
    // require the exact stream an uninterrupted run produces.
    let cluster = ClusterSpec::mini();
    let policy = SimPolicy {
        locality_wait: 0.2,
        speculation: Some(SpecPolicy { quantile: 0.6, multiplier: 1.4 }),
    };
    let submit_all = |sim: &mut EventSim<'_>| {
        sim.set_pool(1, PoolSpec { weight: 2.0, min_share: 1 });
        for j in 0..3usize {
            sim.submit(
                j,
                &mixed_tasks(14, 4, j % 2 == 0),
                &SimOpts {
                    jitter: 0.05,
                    seed: 21 + j as u64,
                    straggler: Some(Straggler { prob: 0.2, factor: 5.0 }),
                },
            );
        }
    };
    for discovery in [Discovery::Scan, Discovery::Indexed] {
        let mut whole = EventSim::with_discovery(
            &cluster,
            scheduler_for(SchedulerMode::Fair),
            policy,
            discovery,
        );
        submit_all(&mut whole);
        let all = whole.drain();

        let mut head = EventSim::with_discovery(
            &cluster,
            scheduler_for(SchedulerMode::Fair),
            policy,
            discovery,
        );
        submit_all(&mut head);
        let first = head.advance().expect("work pending");
        let cp = head.checkpoint();
        // The resumed core inherits the checkpoint's discovery mode, so
        // the Scan pass re-verifies every restored invariant event by
        // event.
        let mut tail = EventSim::resume(&cluster, scheduler_for(SchedulerMode::Fair), &cp);
        let mut rest = vec![first];
        rest.extend(tail.drain());
        assert_streams_identical(&all, &rest, &format!("{discovery:?} checkpoint resume"));
        assert_eq!(tail.stats().logical(), whole.stats().logical(), "{discovery:?}");
        assert_eq!(tail.stats().forked_trials, 1);
        assert_eq!(tail.stats().replayed_events, cp.events());
    }
}

#[test]
fn fork_store_byte_eviction_is_bounded_and_lossless() {
    // Seven distinct fork families (extras diffs are Global — every
    // family is a separate full recording) blow through a byte budget
    // sized for two recordings; every trial — recorded, forked, or
    // priced after its family was evicted — must still equal full
    // pricing bit for bit, and the victim must be the
    // *least-recently-matched* recording, not the oldest insertion.
    use sparktune::tuner::ForkingRunner;
    let cluster = ClusterSpec::mini();
    let plan = prepare(&iterative_job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let family = |i: u32| SparkConf::default().with("spark.yarn.queue", &format!("q{i}"));
    let mut runner = ForkingRunner::new(Arc::clone(&plan), &cluster, opts.clone());
    let _ = runner.run_result(&family(0));
    // Extras don't touch pricing, so every family's recording has the
    // same footprint: a budget of 2.5× one recording holds exactly two.
    let one = runner.checkpoint_bytes() as usize;
    assert!(one > 0, "a recording has a real footprint");
    runner.set_fork_budget(one * 5 / 2);
    for i in 1..6u32 {
        let conf = family(i);
        let a = runner.run_result(&conf);
        let b = run_planned(&plan, &conf, &cluster, &opts);
        assert!(job_results_identical(&a, &b), "family {i} diverged");
        assert!(
            runner.checkpoint_bytes() <= runner.fork_budget_bytes() as u64,
            "store must stay within its byte budget"
        );
        assert!(runner.forks_recorded() <= 2, "budget holds two recordings");
    }
    assert_eq!(runner.forked_trials(), 0, "global (extras) diffs never fork");
    // Residents are now families 4 and 5. Matching family 4 with a
    // shuffle-class variant forks — and refreshes its priority.
    let resident = family(4).with("spark.serializer", "kryo");
    let a = runner.run_result(&resident);
    let b = run_planned(&plan, &resident, &cluster, &opts);
    assert!(job_results_identical(&a, &b), "resident-family fork diverged");
    assert_eq!(a.sim.logical(), b.sim.logical());
    assert_eq!(runner.forked_trials(), 1);
    // Recording family 6 must evict the least-recently-matched entry:
    // family 5 (never matched), not family 4 (matched above) — under
    // the old FIFO store the refreshed family would be the victim.
    let _ = runner.run_result(&family(6));
    let pinned = family(4).with("spark.shuffle.compress", "false");
    let a = runner.run_result(&pinned);
    let b = run_planned(&plan, &pinned, &cluster, &opts);
    assert!(job_results_identical(&a, &b), "pinned-family fork diverged");
    assert_eq!(a.sim.logical(), b.sim.logical());
    assert_eq!(runner.forked_trials(), 2, "the matched family must survive the eviction");
    // An evicted family's variant re-prices in full (and re-records) —
    // never resumes a wrong timeline.
    let evicted = family(5).with("spark.serializer", "kryo");
    let a = runner.run_result(&evicted);
    let b = run_planned(&plan, &evicted, &cluster, &opts);
    assert!(job_results_identical(&a, &b), "evicted-family reprice diverged");
    assert_eq!(a.sim, b.sim, "an evicted family must price in full, not fork");
    assert_eq!(runner.forked_trials(), 2, "no fork for the evicted family");
    assert!(runner.checkpoint_bytes() <= runner.fork_budget_bytes() as u64);
}

#[test]
fn mid_stage_resume_matches_full_bitwise_across_the_matrix() {
    // A 19-stage kmeans produces 18 new-wave barriers — two more than
    // the recorder keeps — so the newest checkpoint is a cadence
    // snapshot taken *inside* a late stage. A certified locality-wait
    // delta resumes from it (the coarse oracle can't fork at all) and
    // must equal the full-reprice oracle bit for bit across FIFO/FAIR
    // × speculation × straggler.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&workloads::kmeans(400_000, 32, 8, 9, 16)).unwrap();
    let bases = [
        ("fifo", SparkConf::default()),
        ("fair", SparkConf::default().with("spark.scheduler.mode", "FAIR")),
        ("speculation", SparkConf::default().with("spark.speculation", "true")),
    ];
    let opt_sets = [
        ("plain", SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None }),
        (
            "straggler",
            SimOpts {
                jitter: 0.05,
                seed: 0xBEEF,
                straggler: Some(Straggler { prob: 0.1, factor: 6.0 }),
            },
        ),
    ];
    for (bname, base) in &bases {
        for (oname, opts) in &opt_sets {
            let (rec, fork) = run_planned_recording(&plan, base, &cluster, opts);
            let plain = run_planned(&plan, base, &cluster, opts);
            assert!(job_results_identical(&rec, &plain), "{bname}/{oname}: recording diverged");
            assert!(fork.mid_stage_checkpoints() > 0, "{bname}/{oname}: no cadence snapshots");
            let patient = base.clone().with("spark.locality.wait", "6s");
            assert!(
                fork.resumes_mid_stage(&plan, &patient),
                "{bname}/{oname}: the locality delta must resume from an intra-stage snapshot"
            );
            assert_eq!(
                fork.shared_prefix_events_with(&plan, &patient, true),
                None,
                "{bname}/{oname}: the coarse oracle calls locality Global"
            );
            let full = run_planned(&plan, &patient, &cluster, opts);
            let forked = run_planned_from(&fork, &plan, &patient, &cluster, opts)
                .unwrap_or_else(|| panic!("{bname}/{oname}: mid-stage fork declined"));
            assert!(
                job_results_identical(&full, &forked),
                "{bname}/{oname}: mid-stage forked result diverged from full pricing"
            );
            assert_eq!(
                forked.sim.logical(),
                full.sim.logical(),
                "{bname}/{oname}: logical core counters diverged"
            );
            assert_eq!(
                fork.shared_prefix_events(&plan, &patient),
                Some(forked.sim.replayed_events),
                "{bname}/{oname}: the resume point is the first divergent event"
            );
            assert!(
                forked.sim.processed_events() < full.sim.events,
                "{bname}/{oname}: mid-stage fork processed {} of {} events",
                forked.sim.processed_events(),
                full.sim.events
            );
        }
    }
}

#[test]
fn service_incremental_repricing_is_worker_count_invariant() {
    // Sessions served with incremental re-pricing on must be bitwise
    // equal to the full-reprice oracle for every worker count — the fork
    // store is a shared mutable structure, but any trial it serves is
    // bit-identical to full pricing, so outcomes cannot depend on which
    // session recorded or resumed what.
    use sparktune::service::{outcomes_identical, ServiceOpts, SessionRequest, TuningService};
    use sparktune::tuner::TuneOpts;
    let reqs: Vec<SessionRequest> = (0..3)
        .map(|i| SessionRequest {
            name: format!("km{i}"),
            job: iterative_job(),
            tune: TuneOpts::default(),
            sim: SimOpts { jitter: 0.04, seed: 0x7E57 + (i % 2) as u64, straggler: None },
        })
        .collect();
    let oracle = TuningService::new(
        ClusterSpec::mini(),
        ServiceOpts { full_reprice: true, ..ServiceOpts::default() },
    );
    let reference = oracle.serve(&reqs);
    assert_eq!(oracle.stats().forked_trials, 0, "oracle never forks");
    for workers in [1usize, 4, 8] {
        let svc = TuningService::new(
            ClusterSpec::mini(),
            ServiceOpts { workers, ..ServiceOpts::default() },
        );
        let out = svc.serve(&reqs);
        for (x, y) in reference.iter().zip(&out) {
            assert!(
                outcomes_identical(&x.outcome, &y.outcome),
                "workers={workers}: session {} diverged from the oracle",
                x.name
            );
        }
        let s = svc.stats();
        assert!(s.forked_trials > 0, "workers={workers}: no trial forked");
        assert!(s.replayed_events > 0, "workers={workers}: nothing replayed");
    }
}

#[test]
fn shared_plan_is_thread_safe_and_thread_invariant() {
    // Many worker threads pricing one Arc<JobPlan> concurrently must
    // reproduce the sequential results bit for bit (the tuner's
    // parallel-trials contract on the new hot path).
    use sparktune::tuner::TrialExecutor;
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let confs: Vec<SparkConf> = (0..24).map(|i| grid_conf(i * 5 % grid_size())).collect();
    let eval = |c: &SparkConf| run_planned(&plan, c, &cluster, &opts).effective_duration();
    let seq = TrialExecutor::new(1).evaluate(&confs, eval);
    for threads in [2usize, 4, 8] {
        let par = TrialExecutor::new(threads).evaluate(&confs, eval);
        assert_eq!(seq, par, "{threads}-thread planned trials diverged");
    }
}
