//! Golden equivalence suite for the event-core hot-path overhaul.
//!
//! The indexed event queue (hand-rolled min-heap + dirty-resource rate
//! propagation + launch-ordered speculation queues) must be a pure
//! *performance* change: for any scenario, the [`Discovery::Indexed`]
//! core reproduces the self-verifying [`Discovery::Scan`] reference —
//! which rescans every live copy per event and asserts the cached
//! fair-share rates fresh — **bit for bit**, across FIFO/FAIR,
//! delay scheduling, speculation, the straggler model, mid-flight
//! submission, and degenerate stages. Likewise, plan-once pricing
//! (`prepare` + `run_planned`) must be bit-identical to re-planning per
//! trial, for solo runs, multi-tenant batches, and crashing confs.

use sparktune::cluster::{ClusterSpec, NodeId};
use sparktune::conf::SparkConf;
use sparktune::engine::{prepare, run, run_all, run_all_planned, run_planned, Job, JobPlan};
use sparktune::sim::{
    scheduler_for, Discovery, EventSim, PoolSpec, SchedulerMode, SimOpts, SimPolicy, SimStats,
    SpecPolicy, StageCompletion, Straggler, TaskSpec,
};
use sparktune::sim::Phase;
use sparktune::tuner::baselines::{grid_conf, grid_size};
use sparktune::workloads::{self, Workload};
use std::sync::Arc;

/// Bitwise comparison of two completion streams: event order, clocks,
/// meters, locality/speculation counters, and winning-node placements.
fn assert_streams_identical(scan: &[StageCompletion], indexed: &[StageCompletion], what: &str) {
    assert_eq!(scan.len(), indexed.len(), "{what}: completion counts diverged");
    for (x, y) in scan.iter().zip(indexed) {
        assert_eq!(x.handle, y.handle, "{what}: emission order diverged");
        assert_eq!(x.job, y.job, "{what}");
        assert_eq!(x.at.to_bits(), y.at.to_bits(), "{what}: clock diverged at stage {}", x.handle);
        assert_eq!(x.stats.duration.to_bits(), y.stats.duration.to_bits(), "{what}");
        assert_eq!(x.stats.cpu_secs.to_bits(), y.stats.cpu_secs.to_bits(), "{what}");
        assert_eq!(x.stats.disk_bytes.to_bits(), y.stats.disk_bytes.to_bits(), "{what}");
        assert_eq!(x.stats.net_bytes.to_bits(), y.stats.net_bytes.to_bits(), "{what}");
        assert_eq!(x.stats.tasks, y.stats.tasks, "{what}");
        assert_eq!(x.stats.locality_hits, y.stats.locality_hits, "{what}");
        assert_eq!(x.stats.speculated, y.stats.speculated, "{what}");
        assert_eq!(x.task_nodes, y.task_nodes, "{what}: winning placements diverged");
    }
}

/// Run the same scripted scenario on both cores and compare streams.
fn both_cores(
    cluster: &ClusterSpec,
    mode: SchedulerMode,
    policy: SimPolicy,
    what: &str,
    script: impl Fn(&mut EventSim<'_>) -> Vec<StageCompletion>,
) -> (SimStats, SimStats) {
    let mut scan = EventSim::with_discovery(cluster, scheduler_for(mode), policy, Discovery::Scan);
    let scan_out = script(&mut scan);
    let mut idx =
        EventSim::with_discovery(cluster, scheduler_for(mode), policy, Discovery::Indexed);
    let idx_out = script(&mut idx);
    assert_streams_identical(&scan_out, &idx_out, what);
    (scan.stats(), idx.stats())
}

/// A mixed-phase task set exercising every phase kind and node.
fn mixed_tasks(n: usize, nodes: u32, pin: bool) -> Vec<TaskSpec> {
    (0..n)
        .map(|k| {
            let t = TaskSpec::new(vec![
                Phase::Fixed { secs: 0.002 * (1 + k % 3) as f64 },
                Phase::NetIn { bytes: 0.5e6 * (1 + k % 5) as f64 },
                Phase::DiskRead { bytes: 1e6 * (1 + k % 4) as f64 },
                Phase::Cpu { secs: 0.05 + (k % 7) as f64 * 0.02 },
                Phase::DiskWrite { bytes: 2e6 },
            ]);
            if pin {
                t.on((k as u32 % nodes) as NodeId)
            } else {
                t
            }
        })
        .collect()
}

#[test]
fn fifo_and_fair_multi_job_streams_match() {
    let cluster = ClusterSpec::mini();
    for mode in SchedulerMode::ALL {
        let (ss, is) = both_cores(
            &cluster,
            mode,
            SimPolicy::default(),
            &format!("{mode} multi-job"),
            |sim| {
                for j in 0..4usize {
                    sim.submit(
                        j,
                        &mixed_tasks(18, 4, j % 2 == 0),
                        &SimOpts { jitter: 0.06, seed: 40 + j as u64, straggler: None },
                    );
                }
                sim.drain()
            },
        );
        assert_eq!(ss.events, is.events, "{mode}: event counts diverged");
        assert_eq!(ss.heap_ops(), 0);
        assert!(is.heap_ops() > 0);
    }
}

#[test]
fn locality_wait_hold_and_expiry_streams_match() {
    // Pinned tasks contend for two nodes under a range of waits: holds,
    // hold-expiry events, and degradation to ANY all cross the cores.
    let mut cluster = ClusterSpec::mini();
    cluster.nodes = 2;
    cluster.cores_per_node = 2;
    for wait in [0.0, 0.05, 0.4, 5.0] {
        both_cores(
            &cluster,
            SchedulerMode::Fifo,
            SimPolicy { locality_wait: wait, speculation: None },
            &format!("locality wait {wait}"),
            |sim| {
                for j in 0..3usize {
                    let tasks: Vec<TaskSpec> = (0..8)
                        .map(|k| {
                            TaskSpec::new(vec![Phase::Cpu { secs: 0.2 + (k % 3) as f64 * 0.05 }])
                                .on(0)
                        })
                        .collect();
                    sim.submit(
                        j,
                        &tasks,
                        &SimOpts { jitter: 0.03, seed: 9 + j as u64, straggler: None },
                    );
                }
                sim.drain()
            },
        );
    }
}

#[test]
fn speculation_and_straggler_streams_match() {
    // Clone launches, first-finisher-wins races, sibling cancellation
    // with mid-stream flow withdrawal and meter refunds.
    let cluster = ClusterSpec::mini();
    for (quantile, multiplier) in [(0.75, 1.5), (0.3, 1.2)] {
        both_cores(
            &cluster,
            SchedulerMode::Fair,
            SimPolicy {
                locality_wait: 0.1,
                speculation: Some(SpecPolicy { quantile, multiplier }),
            },
            &format!("speculation q={quantile} m={multiplier}"),
            |sim| {
                sim.set_pool(1, PoolSpec { weight: 2.0, min_share: 1 });
                for j in 0..3usize {
                    sim.submit(
                        j,
                        &mixed_tasks(16, 4, true),
                        &SimOpts {
                            jitter: 0.05,
                            seed: 77 + j as u64,
                            straggler: Some(Straggler { prob: 0.3, factor: 8.0 }),
                        },
                    );
                }
                sim.drain()
            },
        );
    }
}

#[test]
fn mid_flight_submission_streams_match() {
    // Stages arriving while the core is busy (the engine's DAG-walk
    // pattern): drain one completion, submit more, repeat.
    let cluster = ClusterSpec::mini();
    both_cores(
        &cluster,
        SchedulerMode::Fifo,
        SimPolicy { locality_wait: 0.2, speculation: None },
        "mid-flight submission",
        |sim| {
            let mut out = Vec::new();
            let o = |seed: u64| SimOpts { jitter: 0.04, seed, straggler: None };
            sim.submit(0, &mixed_tasks(10, 4, true), &o(1));
            sim.submit(1, &[], &o(2));
            out.push(sim.advance().expect("empty stage completes"));
            // Submit against a busy cluster, including a NaN-phase task
            // (must degrade to a noop, not wedge either core).
            sim.submit(
                2,
                &[
                    TaskSpec::new(vec![Phase::Cpu { secs: f64::NAN }, Phase::Cpu { secs: 0.3 }]),
                    TaskSpec::new(vec![Phase::DiskWrite { bytes: 5e6 }]).on(1),
                ],
                &o(3),
            );
            out.push(sim.advance().expect("more work pending"));
            sim.submit(0, &mixed_tasks(6, 4, false), &o(4));
            out.extend(sim.drain());
            assert!(sim.advance().is_none());
            out
        },
    );
}

#[test]
fn indexed_core_does_strictly_less_scan_work() {
    // The CI acceptance counter: on a real multi-wave scenario the
    // indexed core's dirty-resource flow rolls must be strictly fewer
    // than events × live copies (what per-event rescans would touch).
    let cluster = ClusterSpec::mini();
    let (ss, is) = both_cores(
        &cluster,
        SchedulerMode::Fifo,
        SimPolicy::default(),
        "scan-work budget",
        |sim| {
            for j in 0..2usize {
                sim.submit(
                    j,
                    &mixed_tasks(64, 4, false),
                    &SimOpts { jitter: 0.05, seed: 5 + j as u64, straggler: None },
                );
            }
            sim.drain()
        },
    );
    // Both cores rolled the same flows (shared dirty rule)...
    assert_eq!(ss.flow_rolls, is.flow_rolls);
    // ...and that is strictly below the rescan-equivalent work.
    assert!(is.events > 0);
    assert!(
        is.flow_rolls < is.live_copy_event_sum,
        "indexed core rolled {} flows vs {} rescan-equivalent",
        is.flow_rolls,
        is.live_copy_event_sum
    );
    assert!(is.scan_work_saved() > 0);
}

// ---------- plan once / price many ----------

type EngineResult = sparktune::engine::JobResult;

fn job_results_identical(a: &EngineResult, b: &EngineResult) -> bool {
    a.job == b.job
        && a.duration.to_bits() == b.duration.to_bits()
        && a.crashed == b.crashed
        && a.stages.len() == b.stages.len()
        && a.stages.iter().zip(&b.stages).all(|(x, y)| {
            x.name == y.name
                && x.duration.to_bits() == y.duration.to_bits()
                && x.cpu_secs.to_bits() == y.cpu_secs.to_bits()
                && x.disk_bytes.to_bits() == y.disk_bytes.to_bits()
                && x.net_bytes.to_bits() == y.net_bytes.to_bits()
                && x.spilled_bytes == y.spilled_bytes
                && x.gc_factor.to_bits() == y.gc_factor.to_bits()
                && x.cache_hit_fraction.map(f64::to_bits) == y.cache_hit_fraction.map(f64::to_bits)
                && x.locality_hits == y.locality_hits
                && x.speculated == y.speculated
        })
}

#[test]
fn plan_once_matches_replanning_across_the_grid() {
    // One job, a spread of grid candidates (including crashing memory
    // geometries): sharing the plan must not change a bit of any result.
    let cluster = ClusterSpec::mini();
    let job = Workload::MiniSortByKey.job();
    let plan = prepare(&job).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    for i in 0..24 {
        let conf = grid_conf(i * 9 % grid_size());
        let fresh = run(&job, &conf, &cluster, &opts);
        let shared = run_planned(&plan, &conf, &cluster, &opts);
        assert!(job_results_identical(&fresh, &shared), "grid conf {i} diverged");
    }
}

#[test]
fn plan_once_matches_replanning_for_kmeans_and_speculation() {
    // The iterative DAG (cache writer + per-iteration parents) is the
    // planner's hardest shape; cross it with the task-granular knobs.
    let cluster = ClusterSpec::marenostrum();
    let job = Workload::KMeans100M.job();
    let plan = prepare(&job).unwrap();
    let conf = SparkConf::default()
        .with("spark.speculation", "true")
        .with("spark.locality.wait", "1s");
    let opts = SimOpts {
        jitter: 0.04,
        seed: 0xBEEF,
        straggler: Some(Straggler { prob: 0.03, factor: 8.0 }),
    };
    let fresh = run(&job, &conf, &cluster, &opts);
    let shared = run_planned(&plan, &conf, &cluster, &opts);
    assert!(fresh.crashed.is_none());
    assert!(job_results_identical(&fresh, &shared));
    assert_eq!(fresh.sim, shared.sim, "identical work counters");
}

#[test]
fn planned_multi_tenant_batch_matches_replanned() {
    let cluster = ClusterSpec::mini();
    let jobs: Vec<Job> = workloads::mixed_tenants(3, 2_000_000, 16);
    let plans: Vec<Arc<JobPlan>> = jobs.iter().map(|j| prepare(j).unwrap()).collect();
    for mode in ["FIFO", "FAIR"] {
        let conf = SparkConf::default().with("spark.scheduler.mode", mode);
        let a = run_all(&jobs, &conf, &cluster, &SimOpts::default());
        let b = run_all_planned(&plans, &conf, &cluster, &SimOpts::default());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{mode}");
        for (x, y) in a.results.iter().zip(&b.results) {
            assert!(job_results_identical(x, y), "{mode}: {} diverged", x.job);
        }
    }
}

#[test]
fn shared_plan_is_thread_safe_and_thread_invariant() {
    // Many worker threads pricing one Arc<JobPlan> concurrently must
    // reproduce the sequential results bit for bit (the tuner's
    // parallel-trials contract on the new hot path).
    use sparktune::tuner::TrialExecutor;
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let confs: Vec<SparkConf> = (0..24).map(|i| grid_conf(i * 5 % grid_size())).collect();
    let eval = |c: &SparkConf| run_planned(&plan, c, &cluster, &opts).effective_duration();
    let seq = TrialExecutor::new(1).evaluate(&confs, eval);
    for threads in [2usize, 4, 8] {
        let par = TrialExecutor::new(threads).evaluate(&confs, eval);
        assert_eq!(seq, par, "{threads}-thread planned trials diverged");
    }
}
