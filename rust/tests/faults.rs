//! Golden suite for fault injection & Spark-faithful recovery.
//!
//! The injector must be invisible when disarmed: for every scenario in
//! the hot-path matrix (FIFO/FAIR × locality × speculation × straggler)
//! a run through the faulted entry points with a disarmed [`FaultPlan`]
//! reproduces the plain run **bit for bit** — durations, crash flags,
//! and every [`SimStats`] work counter. Armed, the same seed must give
//! the same run on any thread count, traced or untraced, and a fork
//! resume under injection must equal full pricing bit for bit. The
//! recovery semantics themselves — retries up to
//! `spark.task.maxFailures`, FetchFailed parent-stage resubmission
//! bounded by `spark.stage.maxConsecutiveAttempts`, executor restarts —
//! are pinned against hand-checked scenarios.

use sparktune::cluster::ClusterSpec;
use sparktune::conf::SparkConf;
use sparktune::engine::{
    prepare, run_planned, run_planned_faulted, run_planned_faulted_traced,
    run_planned_from_faulted, run_planned_recording_faulted, Job, JobResult,
};
use sparktune::obs::{SpanId, TraceSink};
use sparktune::sim::{FaultPlan, FlakyNode, NodeLoss, SimOpts, Straggler};
use sparktune::workloads::{self, Workload};
use std::sync::Arc;

/// Bitwise result identity — durations, crash flags, stage reports.
/// [`SimStats`] equality is asserted separately where the two runs use
/// the *same* pricing mode: a fork resume legitimately differs from a
/// full run in bookkeeping counters (`forked_trials`,
/// `replayed_events`) while producing the identical result.
fn job_results_identical(a: &JobResult, b: &JobResult) -> bool {
    a.job == b.job
        && a.duration.to_bits() == b.duration.to_bits()
        && a.crashed == b.crashed
        && a.stages.len() == b.stages.len()
        && a.stages.iter().zip(&b.stages).all(|(x, y)| {
            x.name == y.name
                && x.duration.to_bits() == y.duration.to_bits()
                && x.cpu_secs.to_bits() == y.cpu_secs.to_bits()
                && x.disk_bytes.to_bits() == y.disk_bytes.to_bits()
                && x.net_bytes.to_bits() == y.net_bytes.to_bits()
                && x.locality_hits == y.locality_hits
                && x.speculated == y.speculated
        })
}

/// Iterative cache-prefixed workload (same shape as the hot-path
/// suite): the prefix is insensitive to shuffle-class deltas, so the
/// fork-resume path has a real timeline to inherit — under injection.
fn iterative_job() -> Job {
    workloads::kmeans(400_000, 32, 8, 3, 16)
}

/// An armed plan that exercises all three hazard classes: a plan-wide
/// transient crash hazard, a flaky (but survivable) node, and an
/// executor loss timed early inside the fault-free makespan (so it is
/// guaranteed to fire) with a later restart.
fn armed_plan(makespan: f64) -> FaultPlan {
    FaultPlan {
        seed: 0xD00D,
        task_crash_prob: 0.03,
        flaky: Some(FlakyNode { node: 2, crash_prob: 0.2 }),
        losses: vec![NodeLoss {
            node: 3,
            at: 0.2 * makespan,
            restart_after: Some(0.3 * makespan),
        }],
    }
}

#[test]
fn disarmed_injector_is_bit_identical_across_the_matrix() {
    // faults = None (or a disarmed plan) must keep every existing
    // scenario bit-identical: the faulted entry points share one event
    // core with the plain ones, and an unarmed core draws nothing.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let disarmed = FaultPlan::default();
    assert!(!disarmed.is_armed());

    let confs = [
        ("fifo", SparkConf::default()),
        ("fair", SparkConf::default().with("spark.scheduler.mode", "FAIR")),
        ("locality", SparkConf::default().with("spark.locality.wait", "1s")),
        ("speculation", SparkConf::default().with("spark.speculation", "true")),
        (
            "speculation+greedy",
            SparkConf::default()
                .with("spark.speculation", "true")
                .with("spark.locality.wait", "0s"),
        ),
    ];
    let opt_sets = [
        ("plain", SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None }),
        (
            "straggler",
            SimOpts {
                jitter: 0.05,
                seed: 0xBEEF,
                straggler: Some(Straggler { prob: 0.1, factor: 6.0 }),
            },
        ),
        ("no-jitter", SimOpts { jitter: 0.0, seed: 1, straggler: None }),
    ];
    for (cname, conf) in &confs {
        for (oname, opts) in &opt_sets {
            let plain = run_planned(&plan, conf, &cluster, opts);
            let faulted = run_planned_faulted(&plan, conf, &cluster, opts, &disarmed);
            assert!(
                job_results_identical(&plain, &faulted),
                "{cname}/{oname}: a disarmed injector perturbed the run"
            );
            assert_eq!(
                plain.sim, faulted.sim,
                "{cname}/{oname}: a disarmed injector perturbed the work counters"
            );
        }
    }
}

#[test]
fn same_seed_fault_runs_reproduce_across_threads() {
    // The fault draws hash (stage seed, task, attempt, node) — no
    // global RNG — so an armed run is a pure function of its inputs
    // and must survive any thread count bit for bit.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let conf = SparkConf::default();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let makespan = run_planned(&plan, &conf, &cluster, &opts).duration;
    let faults = armed_plan(makespan);

    let serial = run_planned_faulted(&plan, &conf, &cluster, &opts, &faults);
    assert!(faults.is_armed());
    // Either the timed loss fires (the run lasts at least the clean
    // makespan unless a fault already ended it) or a hazard crash
    // pre-empted it — both prove injection actually happened.
    assert!(
        serial.sim.task_failures > 0 || serial.sim.executor_losses > 0,
        "the armed plan must actually inject something"
    );

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (plan, conf, cluster, opts, faults) = (&plan, &conf, &cluster, &opts, &faults);
                s.spawn(move || run_planned_faulted(plan, conf, cluster, opts, faults))
            })
            .collect();
        for h in handles {
            let threaded = h.join().unwrap();
            assert!(
                job_results_identical(&serial, &threaded),
                "same-seed fault run diverged across threads"
            );
            assert_eq!(serial.sim, threaded.sim, "work counters diverged across threads");
        }
    });
}

#[test]
fn traced_equals_untraced_under_injection() {
    // Tracing stays a pure observer with the injector armed, and the
    // exported artifacts are byte-stable run over run.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let conf = SparkConf::default();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let makespan = run_planned(&plan, &conf, &cluster, &opts).duration;
    let faults = armed_plan(makespan);

    let plain = run_planned_faulted(&plan, &conf, &cluster, &opts, &faults);
    let sink = TraceSink::buffered();
    let traced =
        run_planned_faulted_traced(&plan, &conf, &cluster, &opts, &faults, &sink, SpanId::NONE);
    assert!(job_results_identical(&plain, &traced), "tracing perturbed a faulted run");
    assert_eq!(plain.sim, traced.sim, "tracing perturbed faulted work counters");

    let events = sink.events();
    assert!(!events.is_empty(), "a traced faulted run must record spans");
    if plain.sim.executor_losses > 0 {
        assert!(
            events.iter().any(|e| e.cat == "executor"),
            "executor losses must surface as trace instants"
        );
        assert!(
            sink.event_log().contains("SparkListenerExecutorRemoved"),
            "the event log must carry the Spark listener event"
        );
    }

    // Byte-stable exports: a second traced run writes the same files.
    let sink2 = TraceSink::buffered();
    let again =
        run_planned_faulted_traced(&plan, &conf, &cluster, &opts, &faults, &sink2, SpanId::NONE);
    assert!(job_results_identical(&traced, &again));
    assert_eq!(traced.sim, again.sim);
    assert_eq!(sink.chrome_trace(), sink2.chrome_trace());
    assert_eq!(sink.event_log(), sink2.event_log());
}

#[test]
fn fork_resume_under_faults_is_bit_identical_to_full_pricing() {
    // The tentpole acceptance bar: recording under injection equals the
    // plain faulted run, and resuming a shuffle-class probe from the
    // recorded fork equals pricing it from scratch — bit for bit.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&iterative_job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let faults =
        FaultPlan { seed: 0xF0_4C, task_crash_prob: 0.03, flaky: None, losses: Vec::new() };
    let base = SparkConf::default();

    let (recorded, fork) = run_planned_recording_faulted(&plan, &base, &cluster, &opts, &faults);
    let full_base = run_planned_faulted(&plan, &base, &cluster, &opts, &faults);
    assert!(
        job_results_identical(&recorded, &full_base),
        "recording checkpoints perturbed a faulted run"
    );

    let probes = [
        SparkConf::default()
            .with("spark.serializer", "org.apache.spark.serializer.KryoSerializer"),
        SparkConf::default().with("spark.shuffle.compress", "false"),
        SparkConf::default().with("spark.shuffle.file.buffer", "128k"),
    ];
    let mut resumed = 0;
    for probe in &probes {
        let full = run_planned_faulted(&plan, probe, &cluster, &opts, &faults);
        let forked = run_planned_from_faulted(&fork, &plan, probe, &cluster, &opts, &faults);
        if let Some(forked) = forked {
            resumed += 1;
            assert!(
                job_results_identical(&forked, &full),
                "fork resume under faults diverged from full pricing"
            );
        }
    }
    assert!(resumed > 0, "at least one shuffle-class probe must resume from the fork");

    // A probe that changes the failure policy itself may only resume
    // when the certificate proves the prefix failure-free; either way
    // the contract is the same — resume ≡ full pricing.
    let policy_probe = SparkConf::default().with("spark.task.maxFailures", "8");
    let full = run_planned_faulted(&plan, &policy_probe, &cluster, &opts, &faults);
    if let Some(forked) =
        run_planned_from_faulted(&fork, &plan, &policy_probe, &cluster, &opts, &faults)
    {
        assert!(
            job_results_identical(&forked, &full),
            "policy-divergent fork resume diverged from full pricing"
        );
    }
}

#[test]
fn transient_crashes_retry_within_the_budget() {
    // A plan-wide hazard with default maxFailures=4: every failure is
    // retried (speculation off → no live sibling absorbs it), the job
    // finishes, and the rework shows up as extra launches and a longer
    // makespan than the fault-free twin.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let conf = SparkConf::default();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let faults = FaultPlan { seed: 7, task_crash_prob: 0.10, flaky: None, losses: Vec::new() };

    let clean = run_planned(&plan, &conf, &cluster, &opts);
    let r = run_planned_faulted(&plan, &conf, &cluster, &opts, &faults);
    assert!(r.crashed.is_none(), "a 10% hazard must not exhaust maxFailures=4: {:?}", r.crashed);
    assert!(r.sim.task_failures > 0, "a 10% hazard must hit at least one task");
    assert_eq!(
        r.sim.task_retries, r.sim.task_failures,
        "without speculation every failure is retried"
    );
    assert_eq!(r.sim.stage_aborts, 0);
    assert!(r.sim.task_launches > clean.sim.task_launches, "retries launch extra attempts");
    assert!(r.duration >= clean.duration, "doomed attempts burn cluster time");
}

#[test]
fn max_failures_exhaustion_aborts_the_stage() {
    // A black-hole node with maxFailures=1: the first commit there
    // fails and aborts the stage — effective duration is infinite and
    // no retry is ever granted.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let conf = SparkConf::default().with("spark.task.maxFailures", "1");
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let faults = FaultPlan {
        seed: 11,
        task_crash_prob: 0.0,
        flaky: Some(FlakyNode { node: 1, crash_prob: 1.0 }),
        losses: Vec::new(),
    };

    let r = run_planned_faulted(&plan, &conf, &cluster, &opts, &faults);
    assert!(r.crashed.is_some(), "one failure must exhaust maxFailures=1");
    assert!(r.effective_duration().is_infinite());
    assert!(r.sim.stage_aborts >= 1);
    assert_eq!(r.sim.task_retries, 0, "an aborting failure grants no retry");
}

/// Fault-free reference run used to time executor losses inside a
/// specific stage's window (linear DAG ⇒ makespan = Σ stage durations).
fn clean_two_stage(
    plan: &Arc<sparktune::engine::JobPlan>,
    cluster: &ClusterSpec,
    opts: &SimOpts,
) -> (JobResult, f64) {
    let clean = run_planned(plan, &SparkConf::default(), cluster, opts);
    assert!(clean.crashed.is_none());
    assert!(clean.stages.len() >= 2, "need a map stage feeding a reduce stage");
    let mid_reduce = clean.stages[0].duration + 0.5 * clean.stages[1].duration;
    (clean, mid_reduce)
}

#[test]
fn lost_executor_resubmits_the_parent_stage_for_lost_partitions() {
    // Losing a node mid-reduce invalidates its finished shuffle-map
    // outputs: the FetchFailed path resubmits the parent stage for only
    // the lost partitions, surfaced as a "[resubmit N]" stage report,
    // and the job still finishes — slower than fault-free.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let (clean, mid_reduce) = clean_two_stage(&plan, &cluster, &opts);

    let faults = FaultPlan {
        seed: 3,
        task_crash_prob: 0.0,
        flaky: None,
        losses: vec![NodeLoss { node: 1, at: mid_reduce, restart_after: None }],
    };
    let r = run_planned_faulted(&plan, &SparkConf::default(), &cluster, &opts, &faults);
    assert!(r.crashed.is_none(), "default policy must recover: {:?}", r.crashed);
    assert_eq!(r.sim.executor_losses, 1);
    assert_eq!(r.sim.executor_restarts, 0);
    assert!(
        r.stages.iter().any(|s| s.name.contains("[resubmit")),
        "lost map outputs must surface a resubmission report: {:?}",
        r.stages.iter().map(|s| s.name.clone()).collect::<Vec<_>>()
    );
    assert!(
        r.sim.task_launches > clean.sim.task_launches,
        "re-running lost map partitions launches extra tasks"
    );
    assert!(r.duration > clean.duration, "recovery rework costs wall-clock");
}

#[test]
fn stage_max_consecutive_attempts_bounds_fetch_failed_recovery() {
    // With spark.stage.maxConsecutiveAttempts=1, the very first
    // FetchFailed resubmission exceeds the bound: the job crashes
    // instead of retrying forever.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let (_, mid_reduce) = clean_two_stage(&plan, &cluster, &opts);

    let faults = FaultPlan {
        seed: 3,
        task_crash_prob: 0.0,
        flaky: None,
        losses: vec![NodeLoss { node: 1, at: mid_reduce, restart_after: None }],
    };
    let conf = SparkConf::default().with("spark.stage.maxConsecutiveAttempts", "1");
    let r = run_planned_faulted(&plan, &conf, &cluster, &opts, &faults);
    let msg = r
        .crashed
        .as_deref()
        .expect("maxConsecutiveAttempts=1 must turn the resubmission into a crash");
    assert!(msg.contains("FetchFailed"), "the crash must name the FetchFailed bound: {msg}");
    assert!(r.effective_duration().is_infinite());
}

#[test]
fn restarted_executor_rejoins_but_lost_outputs_are_still_repriced() {
    // A restart restores compute capacity, not shuffle outputs: the
    // resubmission still happens, the restart is counted, and the job
    // finishes.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let (clean, mid_reduce) = clean_two_stage(&plan, &cluster, &opts);

    let gone = FaultPlan {
        seed: 3,
        task_crash_prob: 0.0,
        flaky: None,
        losses: vec![NodeLoss { node: 1, at: mid_reduce, restart_after: None }],
    };
    let back = FaultPlan {
        losses: vec![NodeLoss {
            node: 1,
            at: mid_reduce,
            restart_after: Some(0.1 * clean.stages[1].duration),
        }],
        ..gone.clone()
    };
    let r_gone = run_planned_faulted(&plan, &SparkConf::default(), &cluster, &opts, &gone);
    let r_back = run_planned_faulted(&plan, &SparkConf::default(), &cluster, &opts, &back);
    assert!(r_gone.crashed.is_none());
    assert_eq!(r_gone.sim.executor_restarts, 0);
    assert!(r_back.crashed.is_none());
    assert_eq!(r_back.sim.executor_losses, 1);
    assert_eq!(r_back.sim.executor_restarts, 1);
    assert!(
        r_back.stages.iter().any(|s| s.name.contains("[resubmit")),
        "a restart does not resurrect shuffle outputs"
    );
}

#[test]
fn exclusion_caps_how_often_a_flaky_node_is_trusted() {
    // excludeOnFailure turns a black-hole node into a bounded capacity
    // loss: after maxTaskAttemptsPerNode failures the node is excluded
    // and the job finishes, where retries alone would circle forever
    // into an abort (re-queued attempts keep their block placement).
    let cluster = ClusterSpec::mini();
    let plan = prepare(&Workload::MiniSortByKey.job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let faults = FaultPlan {
        seed: 11,
        task_crash_prob: 0.0,
        flaky: Some(FlakyNode { node: 1, crash_prob: 1.0 }),
        losses: Vec::new(),
    };

    let retries_only = run_planned_faulted(&plan, &SparkConf::default(), &cluster, &opts, &faults);
    assert!(
        retries_only.crashed.is_some(),
        "node-local retries re-land on the black hole until maxFailures"
    );

    let excluding = SparkConf::default().with("spark.excludeOnFailure.enabled", "true");
    let r = run_planned_faulted(&plan, &excluding, &cluster, &opts, &faults);
    assert!(r.crashed.is_none(), "exclusion must rescue the job: {:?}", r.crashed);
    assert!(r.sim.task_failures >= 2, "the node earns its exclusion the hard way");
    assert_eq!(r.sim.stage_aborts, 0);
}
