//! Golden suite for the deterministic tracing & metrics plane.
//!
//! The observability plane must be a pure *observer*: for any scenario
//! in the hot-path matrix (FIFO/FAIR × locality × speculation ×
//! straggler × fork-resume), a traced run reproduces the untraced run
//! **bit for bit** — durations, crash flags, and every [`SimStats`]
//! work counter. The exported artifacts (Chrome-trace JSON, the
//! Spark-history-style event log) are stamped with the sim clock and
//! monotonic sequence numbers, never wall time, so repeated runs — and
//! concurrent runs on any number of threads, one sink each — export
//! byte-identical files. Trial provenance records reconcile exactly
//! with the runner and service counters, and per-trial stats absorbed
//! into one [`SimStats`] equal the metrics registry's aggregate of the
//! same per-trial records, field for field.

use sparktune::cluster::ClusterSpec;
use sparktune::conf::SparkConf;
use sparktune::engine::{
    prepare, run_planned, run_planned_from, run_planned_from_traced, run_planned_recording,
    run_planned_recording_traced, run_planned_traced, Job, JobPlan, JobResult,
};
use sparktune::obs::{Registry, SpanId, TraceSink};
use sparktune::service::{ServiceOpts, SessionRequest, TuningService};
use sparktune::sim::{SimOpts, SimStats, Straggler};
use sparktune::tuner::baselines::{grid_conf, grid_size};
use sparktune::tuner::{tune, ForkingRunner, RunProvenance, TuneOpts, TuneOutcome};
use sparktune::workloads;
use std::sync::Arc;

fn job_results_identical(a: &JobResult, b: &JobResult) -> bool {
    a.job == b.job
        && a.duration.to_bits() == b.duration.to_bits()
        && a.crashed == b.crashed
        && a.stages.len() == b.stages.len()
        && a.stages.iter().zip(&b.stages).all(|(x, y)| {
            x.name == y.name
                && x.duration.to_bits() == y.duration.to_bits()
                && x.cpu_secs.to_bits() == y.cpu_secs.to_bits()
                && x.disk_bytes.to_bits() == y.disk_bytes.to_bits()
                && x.net_bytes.to_bits() == y.net_bytes.to_bits()
                && x.locality_hits == y.locality_hits
                && x.speculated == y.speculated
        })
}

/// Iterative cache-prefixed workload (same shape as the hot-path
/// suite): the prefix is insensitive to shuffle-class deltas, so the
/// fork-resume path has a real timeline to inherit — and to trace.
fn iterative_job() -> Job {
    workloads::kmeans(400_000, 32, 8, 3, 16)
}

#[test]
fn traced_runs_are_bit_identical_to_untraced_across_the_matrix() {
    // FIFO/FAIR × speculation+locality × straggler, crossed with all
    // three pricing paths: plain, recording, and checkpoint fork-resume.
    // Tracing on must equal tracing off bit for bit — results *and*
    // work counters — while actually recording a span tree.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&iterative_job()).unwrap();
    let bases = [
        ("fifo", SparkConf::default()),
        ("fair", SparkConf::default().with("spark.scheduler.mode", "FAIR")),
        (
            "speculation+locality",
            SparkConf::default()
                .with("spark.speculation", "true")
                .with("spark.locality.wait", "1s"),
        ),
    ];
    let opt_sets = [
        ("plain", SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None }),
        (
            "straggler",
            SimOpts {
                jitter: 0.05,
                seed: 0xBEEF,
                straggler: Some(Straggler { prob: 0.1, factor: 6.0 }),
            },
        ),
    ];
    for (bname, base) in &bases {
        for (oname, opts) in &opt_sets {
            // Plain pricing.
            let plain = run_planned(&plan, base, &cluster, opts);
            let sink = TraceSink::buffered();
            let traced = run_planned_traced(&plan, base, &cluster, opts, &sink, SpanId::NONE);
            assert!(
                job_results_identical(&plain, &traced),
                "{bname}/{oname}: tracing perturbed the run"
            );
            assert_eq!(plain.sim, traced.sim, "{bname}/{oname}: tracing perturbed the counters");
            assert!(sink.len() > 0, "{bname}/{oname}: traced run recorded nothing");

            // Recording (checkpoint capture must stay invisible too).
            let (rec, fork) = run_planned_recording(&plan, base, &cluster, opts);
            let rsink = TraceSink::buffered();
            let (trec, _tfork) =
                run_planned_recording_traced(&plan, base, &cluster, opts, &rsink, SpanId::NONE);
            assert!(
                job_results_identical(&rec, &trec),
                "{bname}/{oname}: traced recording diverged"
            );
            assert_eq!(rec.sim, trec.sim, "{bname}/{oname}: traced recording counters diverged");

            // Fork-resume under a shuffle-class delta: the traced resume
            // must match the untraced resume bit for bit and annotate
            // the resume point.
            let kryo = base.clone().with("spark.serializer", "kryo");
            let forked = run_planned_from(&fork, &plan, &kryo, &cluster, opts)
                .unwrap_or_else(|| panic!("{bname}/{oname}: fork declined"));
            let fsink = TraceSink::buffered();
            let tforked =
                run_planned_from_traced(&fork, &plan, &kryo, &cluster, opts, &fsink, SpanId::NONE)
                    .unwrap_or_else(|| panic!("{bname}/{oname}: traced fork declined"));
            assert!(
                job_results_identical(&forked, &tforked),
                "{bname}/{oname}: traced fork-resume diverged"
            );
            assert_eq!(forked.sim, tforked.sim, "{bname}/{oname}: traced fork counters diverged");
            assert!(
                fsink.events().iter().any(|e| e.cat == "fork" && e.name.starts_with("resume @")),
                "{bname}/{oname}: fork-resume annotation missing"
            );
        }
    }
}

#[test]
fn null_sink_is_a_true_no_op() {
    // The default path: a null-sink traced run is the untraced run —
    // bit-identical outcome, zero events recorded, empty exports.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&iterative_job()).unwrap();
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let conf = SparkConf::default();
    let plain = run_planned(&plan, &conf, &cluster, &opts);
    let sink = TraceSink::null();
    let traced = run_planned_traced(&plan, &conf, &cluster, &opts, &sink, SpanId::NONE);
    assert!(job_results_identical(&plain, &traced));
    assert_eq!(plain.sim, traced.sim);
    assert_eq!(sink.len(), 0);
    assert!(sink.events().is_empty());
    assert_eq!(sink.chrome_trace(), TraceSink::buffered().chrome_trace());
}

/// One straggler-aware tuner walk through the checkpoint-forking runner
/// with a buffered sink attached; returns the outcome, the runner's
/// counters, and both exports.
fn traced_walk(
    plan: &Arc<JobPlan>,
    cluster: &ClusterSpec,
) -> (TuneOutcome, (u64, u64, u64), String, String) {
    let opts = SimOpts { jitter: 0.04, seed: 0x7E57, straggler: None };
    let sink = TraceSink::buffered();
    let walk =
        TuneOpts { straggler_aware: true, trace: sink.clone(), ..TuneOpts::default() };
    let mut runner = ForkingRunner::new(Arc::clone(plan), cluster, opts);
    let out = tune(&mut runner, &walk);
    let counters = (runner.forked_trials(), runner.replayed_events(), runner.total_events());
    let (chrome, log) = (sink.chrome_trace(), sink.event_log());
    (out, counters, chrome, log)
}

#[test]
fn walk_exports_are_byte_stable_across_runs_and_threads() {
    // The same walk traced twice — and concurrently on four threads,
    // one sink each — must export byte-identical Chrome-trace JSON and
    // event logs: everything is stamped with the sim clock and
    // sequence numbers, never wall time.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&iterative_job()).unwrap();
    let (_, _, chrome, log) = traced_walk(&plan, &cluster);
    let (_, _, chrome2, log2) = traced_walk(&plan, &cluster);
    assert_eq!(chrome, chrome2, "Chrome trace not byte-stable across runs");
    assert_eq!(log, log2, "event log not byte-stable across runs");

    std::thread::scope(|s| {
        let handles: Vec<_> =
            (0..4).map(|_| s.spawn(|| traced_walk(&plan, &cluster))).collect();
        for h in handles {
            let (_, _, tc, tl) = h.join().expect("walk thread panicked");
            assert_eq!(chrome, tc, "Chrome trace diverged across threads");
            assert_eq!(log, tl, "event log diverged across threads");
        }
    });

    // The span tree is real: session, trial, stage, and task spans all
    // land in the log under their Spark-listener analogues, and the
    // fork-resume annotations mark where checkpoints were inherited.
    assert!(log.contains("\"Event\":\"SparkTuneSessionCompleted\""), "{log}");
    assert!(log.contains("\"Event\":\"SparkTuneTrialCompleted\""));
    assert!(log.contains("\"Event\":\"SparkListenerStageCompleted\""));
    assert!(log.contains("\"Event\":\"SparkListenerTaskEnd\""));
    assert!(chrome.contains("\"schema\":\"sparktune.trace.v1\""));
}

#[test]
fn explain_provenance_rows_reconcile_with_runner_counters() {
    // The `tune --explain` contract: the per-trial provenance rows are
    // not narrative — they reconcile *exactly* with the runner's own
    // counters. One row per run, fork rows count to `forked_trials`,
    // replayed/processed sums match the runner's totals to the event.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&iterative_job()).unwrap();
    let (out, (forked, replayed, total_events), _, _) = traced_walk(&plan, &cluster);
    let rows: Vec<RunProvenance> = std::iter::once(out.baseline_provenance)
        .chain(out.trials.iter().map(|t| t.provenance))
        .map(|p| p.expect("the forking runner tracks provenance for every run"))
        .collect();
    assert_eq!(rows.len(), out.runs(), "one provenance row per run");
    assert!(rows.iter().all(|p| !p.memoized), "no memo layer under a bare runner");
    let fork_rows = rows.iter().filter(|p| p.forked).count() as u64;
    assert!(fork_rows > 0, "the walk must resume at least one trial from a checkpoint");
    assert_eq!(fork_rows, forked, "fork rows must equal the runner's forked_trials");
    assert_eq!(
        rows.iter().map(|p| p.replayed_events).sum::<u64>(),
        replayed,
        "replayed-event rows must sum to the runner's total"
    );
    assert_eq!(
        rows.iter().map(|p| p.processed_events).sum::<u64>(),
        total_events,
        "processed-event rows must sum to the runner's total"
    );
    assert!(
        rows.iter().all(|p| p.forked || p.replayed_events == 0),
        "only fork rows inherit events"
    );
}

#[test]
fn service_provenance_reconciles_with_service_stats() {
    // Across a deduping multi-session batch, the per-trial provenance
    // surfaced in every session outcome must reconcile with the
    // service-wide counters: rows == trials requested, non-memo rows ==
    // trials actually simulated, fork rows and replayed sums == the
    // service's fork counters.
    let reqs: Vec<SessionRequest> = (0..3)
        .map(|i| SessionRequest {
            name: format!("km{i}"),
            job: iterative_job(),
            tune: TuneOpts::default(),
            sim: SimOpts { jitter: 0.04, seed: 0x7E57 + (i % 2) as u64, straggler: None },
        })
        .collect();
    let svc = TuningService::new(ClusterSpec::mini(), ServiceOpts::default());
    let sessions = svc.serve(&reqs);
    let stats = svc.stats();
    let rows: Vec<RunProvenance> = sessions
        .iter()
        .flat_map(|s| {
            std::iter::once(s.outcome.baseline_provenance)
                .chain(s.outcome.trials.iter().map(|t| t.provenance))
        })
        .map(|p| p.expect("the service tracks provenance for every run"))
        .collect();
    assert_eq!(rows.len() as u64, stats.trials_requested, "one row per requested trial");
    assert_eq!(
        rows.iter().filter(|p| !p.memoized).count() as u64,
        stats.trials_simulated,
        "non-memo rows must equal the trials actually simulated"
    );
    assert!(rows.iter().any(|p| p.memoized), "overlapping sessions must hit the memo layer");
    assert_eq!(
        rows.iter().filter(|p| p.forked).count() as u64,
        stats.forked_trials,
        "fork rows must equal the service's forked_trials"
    );
    assert!(stats.forked_trials > 0, "incremental re-pricing must engage");
    assert_eq!(
        rows.iter().map(|p| p.replayed_events).sum::<u64>(),
        stats.replayed_events,
        "replayed-event rows must sum to the service counter"
    );
}

#[test]
fn absorbed_stats_equal_registry_aggregate() {
    // Property: pricing N trials and absorbing their stats into one
    // SimStats equals recording each trial's stats into the metrics
    // registry and reading the aggregate back — field for field. The
    // exhaustive destructure below is the drift guard: adding a field
    // to SimStats breaks this test until the registry learns it.
    let cluster = ClusterSpec::mini();
    let plan = prepare(&workloads::Workload::MiniSortByKey.job()).unwrap();
    let reg = Registry::new(4);
    let mut total = SimStats::default();
    for i in 0..12usize {
        let conf = grid_conf(i * 11 % grid_size());
        let straggler = if i % 3 == 0 {
            Some(Straggler { prob: 0.1, factor: 5.0 })
        } else {
            None
        };
        let opts = SimOpts { jitter: 0.04, seed: 0x7E57 + i as u64, straggler };
        let r = run_planned(&plan, &conf, &cluster, &opts);
        total.absorb(&r.sim);
        reg.record_sim_stats("sim", &r.sim);
    }
    let snap = reg.snapshot();
    let SimStats {
        events,
        completions,
        task_launches,
        phase_transitions,
        heap_pushes,
        heap_pops,
        heap_updates,
        flow_rolls,
        live_copy_event_sum,
        admit_probes,
        replayed_events,
        forked_trials,
        task_finishes,
        spec_events,
    } = total;
    for (field, absorbed) in [
        ("sim.events", events),
        ("sim.completions", completions),
        ("sim.task_launches", task_launches),
        ("sim.phase_transitions", phase_transitions),
        ("sim.heap_pushes", heap_pushes),
        ("sim.heap_pops", heap_pops),
        ("sim.heap_updates", heap_updates),
        ("sim.flow_rolls", flow_rolls),
        ("sim.live_copy_event_sum", live_copy_event_sum),
        ("sim.admit_probes", admit_probes),
        ("sim.replayed_events", replayed_events),
        ("sim.forked_trials", forked_trials),
        ("sim.task_finishes", task_finishes),
        ("sim.spec_events", spec_events),
    ] {
        assert_eq!(snap.counter(field), absorbed, "{field}: registry diverged from absorb()");
    }
    assert!(total.events > 0, "the property must exercise real runs");
}

#[test]
fn conf_warnings_flow_into_trace_exports() {
    // An unmodeled key produces a once-per-key warning; routed through
    // the sink it must surface in both export formats.
    let conf = SparkConf::default().with("spark.yarn.queue", "etl");
    assert!(!conf.warnings.is_empty(), "unmodeled keys must warn");
    let sink = TraceSink::buffered();
    for w in &conf.warnings {
        sink.warning(w);
    }
    let log = sink.event_log();
    assert!(log.contains("\"Event\":\"SparkTuneWarning\""), "{log}");
    assert!(log.contains("unmodeled configuration key"), "{log}");
    let chrome = sink.chrome_trace();
    assert!(chrome.contains("\"cat\":\"warning\""), "{chrome}");
}
